//! Prefix-cached paged KV-cache block allocator — vLLM's PagedAttention
//! memory manager plus its automatic prefix caching.
//!
//! A fixed pool of `n_blocks` pages (each holding `block_size` token
//! positions of K/V for all layers) is shared by every sequence in the
//! engine. Sequences get pages appended on demand as they grow and return
//! them on completion, so memory waste is bounded by one partial page per
//! sequence (the paper's "near-zero waste in key-value cache memory", §2).
//!
//! On top of plain paging, pages are **ref-counted and content-hashed**:
//! when a sequence completes, every fully-written page is registered in a
//! cache keyed by `chain_hash(parent_chain, page_tokens)` (hash chained from
//! the sequence start, so identical content at different depths never
//! collides). A later `create_seq` attaches the longest cached block-aligned
//! prefix of its prompt *by reference* instead of re-allocating — chat turns
//! that resend the whole conversation (§2) skip re-prefilling everything but
//! the new suffix. Rules:
//!
//! - **Immutability**: a registered page is never written again. Writing
//!   into a page that is registered or shared (`refs > 1`) first forks it —
//!   copy-on-write — so divergent continuations never corrupt the cache.
//! - **Recompute-one**: at least the last prompt token is always left
//!   uncached, because prefill of that token is what produces the logits
//!   the first sampled token comes from.
//! - **Eviction only under pressure**: unreferenced cached pages sit on an
//!   LRU list and still count as free capacity; an allocation with an empty
//!   free list evicts the least-recently-released cached page. Referenced
//!   pages are never evicted.
//!
//! Block 0 is reserved as the scratch page: inactive batch slots point
//! their entire block table at it so the static-shape HLO always has
//! somewhere safe to write.

use std::collections::{BTreeMap, HashMap};

use anyhow::{bail, Result};

use super::tokenizer;

/// Counters the engine publishes as `llm_prefix_*` metrics.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    /// Prompt tokens served from the cache at `create_seq` time.
    pub hit_tokens: u64,
    /// Cached pages reclaimed under allocation pressure.
    pub evictions: u64,
    /// Copy-on-write page forks (shared/immutable page about to be written).
    pub cow_forks: u64,
    /// Pages registered into the content cache.
    pub registered_blocks: u64,
}

/// Per-page bookkeeping.
#[derive(Debug, Clone, Default)]
struct BlockMeta {
    /// Live sequences referencing this page.
    refs: u32,
    /// Chain hash when the page is registered in the prefix cache
    /// (registered ⇒ content immutable).
    hash: Option<u64>,
    /// Parent chain hash (valid while registered).
    parent: u64,
    /// Token ids filling the page (kept only while registered; used for
    /// partial-tail prefix matching).
    tokens: Vec<i32>,
    /// LRU key while the page is unreferenced-but-cached.
    lru_key: Option<u64>,
}

/// Allocator over the shared page pool.
pub struct BlockAllocator {
    n_blocks: usize,
    block_size: usize,
    max_blocks_per_seq: usize,
    /// Content-free pages, LIFO: recently-freed (cache-warm) pages first.
    free: Vec<u32>,
    blocks: Vec<BlockMeta>,
    /// chain hash → registered page.
    by_hash: HashMap<u64, u32>,
    /// parent chain hash → registered continuation pages (a branching trie).
    children: HashMap<u64, Vec<u32>>,
    /// Unreferenced cached pages in release order (oldest first).
    lru: BTreeMap<u64, u32>,
    tick: u64,
    cache_enabled: bool,
    stats: CacheStats,
}

/// Per-sequence cache state.
#[derive(Debug, Clone)]
pub struct SeqBlocks {
    pub seq_id: u64,
    /// Pool pages in position order (leading pages may be shared).
    blocks: Vec<u32>,
    /// Token positions claimed so far (prompt + generated).
    pub len: usize,
    /// Positions `[0, cached)` were attached from the prefix cache at
    /// `create_seq` time instead of being re-prefilled.
    pub cached: usize,
    /// Positions whose KV has actually been computed (prefill progress,
    /// then decode progress). Only fully-written pages are registrable.
    pub written: usize,
    /// Token id per claimed position — the content the pages are hashed by.
    tokens: Vec<i32>,
}

impl BlockAllocator {
    pub fn new(n_blocks: usize, block_size: usize, max_blocks_per_seq: usize) -> BlockAllocator {
        assert!(n_blocks >= 2, "need at least scratch + one real block");
        BlockAllocator {
            n_blocks,
            block_size,
            max_blocks_per_seq,
            free: (1..n_blocks as u32).rev().collect(),
            blocks: vec![BlockMeta::default(); n_blocks],
            by_hash: HashMap::new(),
            children: HashMap::new(),
            lru: BTreeMap::new(),
            tick: 0,
            cache_enabled: true,
            stats: CacheStats::default(),
        }
    }

    /// Disable/enable content-hash prefix reuse (`EngineConfig.prefix_cache`;
    /// off reproduces the plain paged allocator baseline).
    pub fn set_cache_enabled(&mut self, on: bool) {
        self.cache_enabled = on;
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Pages currently registered in the content cache (shared or evictable).
    pub fn cached_blocks(&self) -> usize {
        self.by_hash.len()
    }

    /// Reclaimable pages: truly free plus unreferenced-cached (evictable).
    pub fn free_blocks(&self) -> usize {
        self.free.len() + self.lru.len()
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Pages needed to hold `tokens` positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Can a new sequence of `prompt_len` tokens be admitted right now?
    /// Conservative: assumes no prefix hit, so admission never fails after
    /// this returns true.
    pub fn can_admit(&self, prompt_len: usize) -> bool {
        self.blocks_for(prompt_len.max(1)) <= self.free_blocks()
    }

    /// Take a page for allocation: free list first, then evict the
    /// least-recently-released cached page. The returned page starts with
    /// one reference and no cache registration.
    fn alloc_block(&mut self) -> Option<u32> {
        let b = match self.free.pop() {
            Some(b) => b,
            None => {
                let (&k, &b) = self.lru.iter().next()?;
                self.lru.remove(&k);
                let m = &mut self.blocks[b as usize];
                let h = m.hash.take().expect("LRU page must be registered");
                let parent = m.parent;
                m.tokens = Vec::new();
                m.lru_key = None;
                self.by_hash.remove(&h);
                let siblings_left = match self.children.get_mut(&parent) {
                    Some(kids) => {
                        kids.retain(|&kb| kb != b);
                        !kids.is_empty()
                    }
                    None => true,
                };
                if !siblings_left {
                    self.children.remove(&parent);
                }
                self.stats.evictions += 1;
                b
            }
        };
        let m = &mut self.blocks[b as usize];
        debug_assert_eq!(m.refs, 0, "allocated page had live refs");
        m.refs = 1;
        Some(b)
    }

    fn take_ref(&mut self, b: u32) {
        let m = &mut self.blocks[b as usize];
        m.refs += 1;
        if let Some(k) = m.lru_key.take() {
            self.lru.remove(&k);
        }
    }

    fn release_ref(&mut self, b: u32) {
        let m = &mut self.blocks[b as usize];
        debug_assert!(m.refs > 0, "double release of block {b}");
        m.refs -= 1;
        if m.refs == 0 {
            if m.hash.is_some() {
                // Retained: evictable, but ready for instant re-attach.
                self.tick += 1;
                m.lru_key = Some(self.tick);
                self.lru.insert(self.tick, b);
            } else {
                self.free.push(b);
            }
        }
    }

    fn release_all(&mut self, blocks: &[u32]) {
        for &b in blocks {
            self.release_ref(b);
        }
    }

    /// Create a sequence for a prompt, attaching the longest cached prefix
    /// by reference and allocating fresh pages for the uncached suffix.
    /// `seq.cached` reports how many prompt positions the cache covered
    /// (always ≤ `tokens.len() - 1`: the last prompt token is recomputed to
    /// produce first-token logits).
    pub fn create_seq(&mut self, seq_id: u64, tokens: &[i32]) -> Result<SeqBlocks> {
        let len = tokens.len();
        let need = self.blocks_for(len.max(1));
        if need > self.max_blocks_per_seq {
            bail!("prompt of {len} tokens exceeds max sequence capacity");
        }

        // --- longest cached block-aligned prefix (full pages) ---
        let mut attached: Vec<u32> = Vec::new();
        let mut chain = 0u64;
        let mut cached = 0usize;
        let mut fork_from: Option<u32> = None;
        if self.cache_enabled && len >= 2 {
            while (attached.len() + 1) * self.block_size <= len - 1 {
                let lo = attached.len() * self.block_size;
                let h = tokenizer::chain_hash(chain, &tokens[lo..lo + self.block_size]);
                match self.by_hash.get(&h) {
                    Some(&b) => {
                        attached.push(b);
                        chain = h;
                        cached = lo + self.block_size;
                    }
                    None => break,
                }
            }
            // --- partial tail: a cached continuation page covering a strict
            // prefix of the remaining tokens. Attaching it means the first
            // uncached write lands *inside* a shared page, so it is forked
            // below — the copy-on-write divergence point.
            let lo = cached;
            if len - lo >= 2 {
                if let Some(kids) = self.children.get(&chain) {
                    let tail = &tokens[lo..len - 1];
                    let mut best: Option<(usize, u32)> = None;
                    for &b in kids {
                        let bt = &self.blocks[b as usize].tokens;
                        let p = tail.iter().zip(bt.iter()).take_while(|(a, c)| a == c).count();
                        if p >= 1 && p > best.map_or(0, |(bp, _)| bp) {
                            best = Some((p, b));
                        }
                    }
                    if let Some((p, b)) = best {
                        fork_from = Some(b);
                        cached = lo + p;
                    }
                }
            }
        }

        // Pin everything we matched before any allocation can evict it.
        for &b in &attached {
            self.take_ref(b);
        }
        if let Some(src) = fork_from {
            self.take_ref(src);
        }
        let mut blocks = attached;

        // The COW fork: a private page conceptually carrying a copy of the
        // shared page's first `cached - lo` KV rows (the sim backend holds
        // no real KV bytes; a real backend would issue a page copy here).
        if let Some(src) = fork_from {
            match self.alloc_block() {
                Some(b) => {
                    blocks.push(b);
                    self.stats.cow_forks += 1;
                    self.release_ref(src);
                }
                None => {
                    // Pinning the fork source can transiently eat the one
                    // reclaimable page `can_admit` budgeted for this spot.
                    // Degrade instead of failing the admission: give up the
                    // partial-tail attach — un-pinning the source makes it
                    // evictable again, so the fresh-page loop below always
                    // succeeds whenever `can_admit` held.
                    self.release_ref(src);
                    cached = blocks.len() * self.block_size;
                }
            }
        }

        // Fresh pages for the remaining (uncached) positions.
        while blocks.len() < need {
            match self.alloc_block() {
                Some(b) => blocks.push(b),
                None => {
                    self.release_all(&blocks);
                    bail!(
                        "kv cache exhausted: need {need} pages, {} reclaimable",
                        self.free_blocks()
                    );
                }
            }
        }

        self.stats.hit_tokens += cached as u64;
        Ok(SeqBlocks {
            seq_id,
            blocks,
            len,
            cached,
            written: cached,
            tokens: tokens.to_vec(),
        })
    }

    /// Grow a sequence by one token (`token` is the id fed at the new
    /// position), allocating a page on a boundary and forking a shared or
    /// registered tail page before it would be written (copy-on-write).
    /// Returns `false` (sequence must be preempted/finished) when the pool
    /// is exhausted or the sequence hit its max length.
    pub fn append_token(&mut self, seq: &mut SeqBlocks, token: i32) -> Result<bool> {
        let needed = self.blocks_for(seq.len + 1);
        if needed > self.max_blocks_per_seq {
            return Ok(false); // sequence is at max context
        }
        if needed > seq.blocks.len() {
            let Some(b) = self.alloc_block() else {
                return Ok(false); // pool exhausted
            };
            seq.blocks.push(b);
        } else {
            // Writing into the existing tail page: immutable or shared
            // pages are forked first so the cache never sees the write.
            let tail = *seq.blocks.last().unwrap();
            let m = &self.blocks[tail as usize];
            if m.hash.is_some() || m.refs > 1 {
                let Some(b) = self.alloc_block() else {
                    return Ok(false);
                };
                self.release_ref(tail);
                *seq.blocks.last_mut().unwrap() = b;
                self.stats.cow_forks += 1;
            }
        }
        seq.tokens.push(token);
        seq.len += 1;
        Ok(true)
    }

    /// Return a sequence's pages to the pool, first registering every
    /// fully-written page into the prefix cache (this is what makes turn
    /// N+1 of a chat hit on turn N's history).
    pub fn free_seq(&mut self, seq: &SeqBlocks) {
        if self.cache_enabled {
            let written = seq.written.min(seq.len).min(seq.tokens.len());
            let mut chain = 0u64;
            for (i, &b) in seq.blocks.iter().enumerate() {
                let hi = (i + 1) * self.block_size;
                if hi > written {
                    break;
                }
                let slice = &seq.tokens[i * self.block_size..hi];
                let h = tokenizer::chain_hash(chain, slice);
                let m = &self.blocks[b as usize];
                if m.hash == Some(h) {
                    chain = h; // attached from the cache; already registered
                    continue;
                }
                if m.hash.is_some() || self.by_hash.contains_key(&h) {
                    // Identical content already cached under another page
                    // (or — defensively — this page is registered under a
                    // different chain): keep the chain, skip the duplicate.
                    chain = h;
                    continue;
                }
                let m = &mut self.blocks[b as usize];
                m.hash = Some(h);
                m.parent = chain;
                m.tokens = slice.to_vec();
                self.by_hash.insert(h, b);
                self.children.entry(chain).or_default().push(b);
                self.stats.registered_blocks += 1;
                chain = h;
            }
        }
        self.release_all(&seq.blocks);
    }

    /// Render the fixed-width block-table row the HLO expects (scratch-page
    /// padded to `max_blocks_per_seq`).
    pub fn table_row(&self, seq: &SeqBlocks) -> Vec<i32> {
        let mut row = vec![0i32; self.max_blocks_per_seq];
        for (i, &b) in seq.blocks.iter().enumerate() {
            row[i] = b as i32;
        }
        row
    }

    /// A row of pure scratch (inactive slot).
    pub fn scratch_row(&self) -> Vec<i32> {
        vec![0i32; self.max_blocks_per_seq]
    }

    /// Invariant check for property tests and (under `debug_assertions`)
    /// every engine iteration: exact partition of the pool into
    /// free / evictable-cached / referenced, exact refcounts, cache-map
    /// consistency, and per-sequence page accounting.
    pub fn check_invariants(&self, live: &[&SeqBlocks]) -> Result<(), String> {
        // Reference counts implied by the live sequences.
        let mut rc = vec![0u32; self.n_blocks];
        for seq in live {
            for &b in &seq.blocks {
                rc[b as usize] += 1;
            }
            if seq.blocks.len() != self.blocks_for(seq.len.max(1)) {
                return Err(format!(
                    "seq {} holds {} pages for {} tokens",
                    seq.seq_id,
                    seq.blocks.len(),
                    seq.len
                ));
            }
            if seq.cached > seq.len {
                return Err(format!("seq {} cached {} > len {}", seq.seq_id, seq.cached, seq.len));
            }
            if seq.tokens.len() != seq.len {
                return Err(format!(
                    "seq {} records {} tokens for {} positions",
                    seq.seq_id,
                    seq.tokens.len(),
                    seq.len
                ));
            }
        }
        if rc[0] != 0 {
            return Err("scratch block referenced by a sequence".into());
        }

        let mut seen = vec![false; self.n_blocks];
        seen[0] = true; // scratch
        for &b in &self.free {
            if b == 0 {
                return Err("scratch block on free list".into());
            }
            if seen[b as usize] {
                return Err(format!("block {b} double-listed"));
            }
            seen[b as usize] = true;
            let m = &self.blocks[b as usize];
            if m.refs != 0 || rc[b as usize] != 0 {
                return Err(format!("free block {b} still referenced"));
            }
            if m.hash.is_some() || m.lru_key.is_some() {
                return Err(format!("free block {b} still registered"));
            }
        }
        for (&k, &b) in &self.lru {
            if seen[b as usize] {
                return Err(format!("block {b} both free and evictable"));
            }
            seen[b as usize] = true;
            let m = &self.blocks[b as usize];
            if m.refs != 0 || rc[b as usize] != 0 {
                return Err(format!("evictable block {b} still referenced"));
            }
            let Some(h) = m.hash else {
                return Err(format!("evictable block {b} not registered"));
            };
            if self.by_hash.get(&h) != Some(&b) {
                return Err(format!("evictable block {b} missing from hash index"));
            }
            if m.lru_key != Some(k) {
                return Err(format!("evictable block {b} LRU key mismatch"));
            }
        }
        for b in 1..self.n_blocks {
            if seen[b] {
                continue;
            }
            let m = &self.blocks[b];
            if m.refs == 0 || m.refs != rc[b] {
                return Err(format!(
                    "block {b} neither free nor evictable: refs={} live-refs={}",
                    m.refs, rc[b]
                ));
            }
            if m.lru_key.is_some() {
                return Err(format!("referenced block {b} still on LRU"));
            }
            if let Some(h) = m.hash {
                if self.by_hash.get(&h) != Some(&(b as u32)) {
                    return Err(format!("referenced block {b} missing from hash index"));
                }
            }
        }

        // Cache maps point at consistently-registered pages.
        for (&h, &b) in &self.by_hash {
            if self.blocks[b as usize].hash != Some(h) {
                return Err(format!("hash index entry for block {b} is stale"));
            }
        }
        let mut child_count = 0usize;
        for (&p, kids) in &self.children {
            for &b in kids {
                child_count += 1;
                let m = &self.blocks[b as usize];
                if m.hash.is_none() || m.parent != p {
                    return Err(format!("children index entry for block {b} is stale"));
                }
            }
        }
        if child_count != self.by_hash.len() {
            return Err(format!(
                "cache indexes disagree: {child_count} children vs {} hashes",
                self.by_hash.len()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::run_prop;

    fn toks(n: usize) -> Vec<i32> {
        (0..n as i32).collect()
    }

    #[test]
    fn alloc_grow_free_cycle() {
        let mut a = BlockAllocator::new(16, 4, 8);
        assert_eq!(a.free_blocks(), 15);
        let mut s = a.create_seq(1, &toks(5)).unwrap(); // 2 pages
        assert_eq!(a.free_blocks(), 13);
        assert_eq!(s.len, 5);
        // Growing to 8 tokens stays in 2 pages; token 9 takes a third.
        for t in 0..3 {
            assert!(a.append_token(&mut s, t).unwrap());
        }
        assert_eq!(a.free_blocks(), 13);
        assert!(a.append_token(&mut s, 9).unwrap());
        assert_eq!(a.free_blocks(), 12);
        a.free_seq(&s);
        assert_eq!(a.free_blocks(), 15);
    }

    #[test]
    fn exhaustion_is_graceful() {
        let mut a = BlockAllocator::new(4, 4, 4); // 3 usable pages
        let s1 = a.create_seq(1, &toks(8)).unwrap(); // 2 pages
        assert!(!a.can_admit(8), "only 1 page left");
        assert!(a.create_seq(2, &toks(8)).is_err());
        let mut s3 = a.create_seq(3, &toks(4)).unwrap(); // last page
        // Growth beyond capacity returns false, not an error.
        assert!(!a.append_token(&mut s3, 7).unwrap());
        a.free_seq(&s1);
        assert!(a.append_token(&mut s3, 7).unwrap());
        a.check_invariants(&[&s3]).unwrap();
    }

    #[test]
    fn max_seq_length_enforced() {
        let mut a = BlockAllocator::new(32, 4, 2); // max 8 tokens/seq
        let mut s = a.create_seq(1, &toks(7)).unwrap();
        assert!(a.append_token(&mut s, 0).unwrap()); // 8th token ok
        assert!(!a.append_token(&mut s, 0).unwrap()); // 9th refused
        assert!(a.create_seq(2, &toks(9)).is_err());
    }

    #[test]
    fn table_row_layout() {
        let mut a = BlockAllocator::new(16, 4, 4);
        let s = a.create_seq(1, &toks(6)).unwrap();
        let row = a.table_row(&s);
        assert_eq!(row.len(), 4);
        assert!(row[0] > 0 && row[1] > 0);
        assert_eq!(&row[2..], &[0, 0], "unused entries point at scratch");
        assert_eq!(a.scratch_row(), vec![0; 4]);
    }

    #[test]
    fn prefix_attach_shares_pages_after_free() {
        let mut a = BlockAllocator::new(32, 4, 8);
        let prompt = toks(13); // 3 full pages + 1 token
        let mut s1 = a.create_seq(1, &prompt).unwrap();
        assert_eq!(s1.cached, 0, "cold cache");
        s1.written = s1.len; // prefill completed
        a.free_seq(&s1);
        assert_eq!(a.cached_blocks(), 3, "three full pages registered");
        assert_eq!(a.free_blocks(), 31, "cached pages still count as capacity");

        // Identical prompt: the three full pages attach by reference and
        // only the 13th token needs recomputation.
        let s2 = a.create_seq(2, &prompt).unwrap();
        assert_eq!(s2.cached, 12);
        assert_eq!(a.stats().hit_tokens, 12);
        a.check_invariants(&[&s2]).unwrap();

        // A third concurrent sequence shares the same pages (refs = 2).
        let s3 = a.create_seq(3, &prompt).unwrap();
        assert_eq!(s3.cached, 12);
        a.check_invariants(&[&s2, &s3]).unwrap();
        a.free_seq(&s2);
        a.free_seq(&s3);
        assert_eq!(a.free_blocks(), 31);
    }

    #[test]
    fn cow_fork_on_partial_tail_attach() {
        let mut a = BlockAllocator::new(32, 4, 8);
        let mut s1 = a.create_seq(1, &toks(8)).unwrap(); // exactly 2 pages
        s1.written = s1.len;
        a.free_seq(&s1);
        assert_eq!(a.cached_blocks(), 2);

        // The same 8-token prompt must still recompute its last token, so
        // the second page is attached partially (3 of 4 tokens) and forked.
        let s2 = a.create_seq(2, &toks(8)).unwrap();
        assert_eq!(s2.cached, 7, "block-aligned prompt caps at len-1");
        assert_eq!(a.stats().cow_forks, 1);
        a.check_invariants(&[&s2]).unwrap();
        // The registered source page survived the fork untouched.
        assert_eq!(a.cached_blocks(), 2);
        a.free_seq(&s2);
    }

    #[test]
    fn divergent_prompt_shares_only_common_prefix() {
        let mut a = BlockAllocator::new(32, 4, 8);
        let mut p1 = toks(12);
        let mut s1 = a.create_seq(1, &p1).unwrap();
        s1.written = s1.len;
        a.free_seq(&s1);
        // Diverge inside the second page: only page 1 matches fully.
        p1[6] = 99;
        let s2 = a.create_seq(2, &p1).unwrap();
        assert_eq!(s2.cached, 4 + 2, "one full page + two partial-tail tokens");
        a.check_invariants(&[&s2]).unwrap();
        a.free_seq(&s2);
    }

    #[test]
    fn eviction_only_under_pressure_and_lru_order() {
        let mut a = BlockAllocator::new(5, 4, 4); // 4 usable pages
        let mut s1 = a.create_seq(1, &toks(8)).unwrap(); // pages A, B
        s1.written = 8;
        a.free_seq(&s1); // A, B registered, evictable (A older)
        let mut s2 = a.create_seq(2, &[9, 9, 9, 9, 9]).unwrap(); // 2 fresh pages
        s2.written = 5;
        assert_eq!(a.stats().evictions, 0, "free pages absorbed the demand");
        // One more page forces eviction of exactly one cached page.
        assert!(a.append_token(&mut s2, 9).unwrap());
        assert!(a.append_token(&mut s2, 9).unwrap());
        assert!(a.append_token(&mut s2, 9).unwrap()); // 8 tokens: 2 pages still
        assert!(a.append_token(&mut s2, 9).unwrap()); // 9th token: 3rd page
        assert_eq!(a.stats().evictions, 1);
        assert_eq!(a.cached_blocks(), 1);
        a.check_invariants(&[&s2]).unwrap();
        a.free_seq(&s2);
    }

    #[test]
    fn referenced_cached_pages_are_never_evicted() {
        let mut a = BlockAllocator::new(4, 4, 4); // 3 usable pages
        let mut s1 = a.create_seq(1, &toks(8)).unwrap();
        s1.written = 8;
        a.free_seq(&s1);
        // Re-attach both full pages... (cached = 7, fork takes the 3rd page)
        let s2 = a.create_seq(2, &toks(8)).unwrap();
        assert_eq!(s2.cached, 7);
        // ...so the pool is now fully pinned: page 1 shared+referenced,
        // page 2 evict... page 2 was released after the fork (refs 0) and
        // already evicted for the fork page if free ran out.
        a.check_invariants(&[&s2]).unwrap();
        // Demanding more pages than exist must fail gracefully, never by
        // evicting a page the live sequence references.
        assert!(a.create_seq(3, &toks(12)).is_err());
        a.check_invariants(&[&s2]).unwrap();
        a.free_seq(&s2);
        assert_eq!(a.free_blocks(), 3);
    }

    #[test]
    fn admission_never_fails_after_can_admit() {
        let mut a = BlockAllocator::new(3, 4, 2); // 2 usable pages
        let mut s1 = a.create_seq(1, &toks(8)).unwrap();
        s1.written = 8;
        a.free_seq(&s1); // both pages cached+evictable; free list empty
        assert!(a.can_admit(8));
        // Pinning the partial-tail fork source would transiently eat the
        // budgeted page; create_seq must degrade to block-aligned reuse
        // (evicting the source for the fresh page), never fail.
        let s2 = a.create_seq(2, &toks(8)).unwrap();
        assert_eq!(s2.cached, 4, "degraded to the block-aligned prefix");
        assert_eq!(a.stats().cow_forks, 0);
        assert_eq!(a.stats().evictions, 1, "fork source evicted for the fresh page");
        a.check_invariants(&[&s2]).unwrap();
        a.free_seq(&s2);
    }

    #[test]
    fn cache_disabled_reproduces_plain_paging() {
        let mut a = BlockAllocator::new(16, 4, 8);
        a.set_cache_enabled(false);
        let mut s1 = a.create_seq(1, &toks(8)).unwrap();
        s1.written = 8;
        a.free_seq(&s1);
        assert_eq!(a.cached_blocks(), 0);
        let s2 = a.create_seq(2, &toks(8)).unwrap();
        assert_eq!(s2.cached, 0);
        assert_eq!(a.stats().hit_tokens, 0);
        a.free_seq(&s2);
        assert_eq!(a.free_blocks(), 15);
    }

    #[test]
    fn prop_allocator_never_double_books() {
        run_prop("kvcache_invariants", 0xcace, 50, |rng| {
            let n_blocks = 4 + rng.below(60) as usize;
            let bs = [4usize, 8, 16][rng.below(3) as usize];
            let max_bps = 1 + rng.below(8) as usize;
            let mut a = BlockAllocator::new(n_blocks, bs, max_bps);
            let mut live: Vec<SeqBlocks> = Vec::new();
            let mut next_id = 0u64;
            // Prompts draw from three shared stems so create_seq exercises
            // full-prefix attach, partial-tail COW forks, and misses.
            let stems: Vec<Vec<i32>> = (0..3)
                .map(|s| (0..(bs * max_bps) as i32).map(|i| i % 7 + s * 100).collect())
                .collect();
            for _ in 0..200 {
                match rng.below(10) {
                    0..=3 => {
                        let plen = 1 + rng.below((bs * max_bps) as u64) as usize;
                        let stem = &stems[rng.below(3) as usize];
                        let mut prompt = stem[..plen].to_vec();
                        if rng.below(2) == 0 {
                            // Mutate one position: divergent suffixes.
                            let at = rng.below(plen as u64) as usize;
                            prompt[at] = 999;
                        }
                        if a.can_admit(plen) && a.blocks_for(plen) <= max_bps {
                            next_id += 1;
                            live.push(a.create_seq(next_id, &prompt).unwrap());
                        }
                    }
                    4..=6 => {
                        if !live.is_empty() {
                            let i = rng.below(live.len() as u64) as usize;
                            let t = rng.below(13) as i32;
                            let _ = a.append_token(&mut live[i], t).unwrap();
                        }
                    }
                    7 => {
                        // Advance prefill progress so freeing registers pages.
                        if !live.is_empty() {
                            let i = rng.below(live.len() as u64) as usize;
                            live[i].written = live[i].len;
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let i = rng.below(live.len() as u64) as usize;
                            let s = live.swap_remove(i);
                            a.free_seq(&s);
                        }
                    }
                }
                let refs: Vec<&SeqBlocks> = live.iter().collect();
                if let Err(e) = a.check_invariants(&refs) {
                    return Err(e);
                }
            }
            // Free everything: every page must be reclaimable again (free
            // or evictable-cached), with nothing leaked or double-booked.
            for s in &live {
                a.free_seq(s);
            }
            live.clear();
            if let Err(e) = a.check_invariants(&[]) {
                return Err(e);
            }
            prop_assert!(
                a.free_blocks() == n_blocks - 1,
                "pool leaked: {} != {}",
                a.free_blocks(),
                n_blocks - 1
            );
            Ok(())
        });
    }
}
