//! Byte-level tokenizer matching `python/compile/model.py`'s vocabulary.
//!
//! Tokens 0..=255 are raw bytes; 256..=259 are BOS/EOS/PAD/UNK. Chosen over
//! BPE so the Rust and Python sides agree by construction (no merges file),
//! while still exercising real encode/decode + incremental UTF-8 assembly
//! on the streaming path.

pub const BOS: i32 = 256;
pub const EOS: i32 = 257;
pub const PAD: i32 = 258;
pub const UNK: i32 = 259;
pub const VOCAB: usize = 260;

/// Encode text to token ids (no specials).
pub fn encode(text: &str) -> Vec<i32> {
    text.bytes().map(|b| b as i32).collect()
}

/// Chained content hash over token ids (FNV-1a), used by the prefix cache:
/// a KV block's identity is `chain_hash(parent_chain, block_tokens)`, so two
/// blocks are interchangeable only when *all* tokens from position 0 up to
/// and including the block agree — exactly the condition under which their
/// KV entries are identical (DESIGN.md §Prefix cache). The root of a chain
/// is parent `0`.
pub fn chain_hash(parent: u64, tokens: &[i32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ parent.rotate_left(17);
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Encode with BOS prepended (prompt form).
pub fn encode_prompt(text: &str) -> Vec<i32> {
    let mut out = Vec::with_capacity(text.len() + 1);
    out.push(BOS);
    out.extend(text.bytes().map(|b| b as i32));
    out
}

/// Decode token ids, skipping specials.
pub fn decode(tokens: &[i32]) -> String {
    let bytes: Vec<u8> =
        tokens.iter().filter(|&&t| (0..256).contains(&t)).map(|&t| t as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// Incremental decoder for token streaming: buffers bytes until they form
/// complete UTF-8 sequences so multi-byte characters never split across SSE
/// events.
#[derive(Default)]
pub struct StreamDecoder {
    pending: Vec<u8>,
}

impl StreamDecoder {
    /// Push one token; returns any newly-completed text.
    pub fn push(&mut self, token: i32) -> String {
        if !(0..256).contains(&token) {
            return String::new();
        }
        self.pending.push(token as u8);
        // Longest valid UTF-8 prefix.
        match std::str::from_utf8(&self.pending) {
            Ok(s) => {
                let out = s.to_string();
                self.pending.clear();
                out
            }
            Err(e) => {
                let valid = e.valid_up_to();
                if valid > 0 {
                    let out =
                        String::from_utf8(self.pending.drain(..valid).collect()).unwrap();
                    out
                } else if self.pending.len() >= 4 {
                    // Invalid sequence: flush lossily rather than stall.
                    let out = String::from_utf8_lossy(&self.pending).into_owned();
                    self.pending.clear();
                    out
                } else {
                    String::new()
                }
            }
        }
    }

    /// Flush any trailing invalid bytes (end of generation).
    pub fn finish(&mut self) -> String {
        let out = String::from_utf8_lossy(&self.pending).into_owned();
        self.pending.clear();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_hash_is_order_and_parent_sensitive() {
        let a = chain_hash(0, &[1, 2, 3]);
        let b = chain_hash(0, &[3, 2, 1]);
        let c = chain_hash(a, &[1, 2, 3]);
        assert_ne!(a, b, "token order must matter");
        assert_ne!(a, c, "parent chain must matter");
        assert_eq!(a, chain_hash(0, &[1, 2, 3]), "deterministic");
        // Identical block content at different depths hashes differently —
        // the property that makes block reuse position-safe.
        assert_ne!(chain_hash(a, &[7, 7]), chain_hash(b, &[7, 7]));
    }

    #[test]
    fn roundtrip_ascii() {
        let text = "Count from 1 to 10.";
        assert_eq!(decode(&encode(text)), text);
    }

    #[test]
    fn roundtrip_unicode() {
        let text = "Göttingen — GWDG 🚀";
        assert_eq!(decode(&encode(text)), text);
    }

    #[test]
    fn prompt_has_bos_and_specials_skipped() {
        let toks = encode_prompt("hi");
        assert_eq!(toks[0], BOS);
        assert_eq!(decode(&toks), "hi");
        assert_eq!(decode(&[BOS, 104, 105, EOS, PAD]), "hi");
    }

    #[test]
    fn stream_decoder_handles_multibyte_split() {
        let mut d = StreamDecoder::default();
        let bytes = "é🚀x".as_bytes();
        let mut out = String::new();
        // Feed byte-by-byte; no intermediate garbage must appear.
        for &b in bytes {
            let chunk = d.push(b as i32);
            assert!(!chunk.contains('\u{FFFD}'));
            out.push_str(&chunk);
        }
        out.push_str(&d.finish());
        assert_eq!(out, "é🚀x");
    }

    #[test]
    fn stream_decoder_skips_specials_and_flushes_invalid() {
        let mut d = StreamDecoder::default();
        assert_eq!(d.push(EOS), "");
        assert_eq!(d.push(0xC3), ""); // dangling continuation start
        assert_eq!(d.finish(), "\u{FFFD}");
    }

    #[test]
    fn stream_decoder_lossy_flush_after_four_invalid_bytes() {
        // A 4-byte-lead byte (0xF0) followed by non-continuation bytes can
        // never become valid UTF-8; after four pending bytes the decoder
        // must flush lossily instead of stalling the stream forever.
        let mut d = StreamDecoder::default();
        assert_eq!(d.push(0xF0), "");
        assert_eq!(d.push(0xF1), "");
        assert_eq!(d.push(0xF2), "");
        let out = d.push(0xF3);
        assert!(!out.is_empty(), "decoder stalled on an invalid sequence");
        assert!(out.chars().all(|c| c == '\u{FFFD}'), "{out:?}");
        // The buffer is clean afterwards: valid text decodes normally.
        assert_eq!(d.push(b'o' as i32), "o");
        assert_eq!(d.push(b'k' as i32), "k");
        assert_eq!(d.finish(), "");
    }

    #[test]
    fn stream_decoder_valid_prefix_drains_before_invalid_tail() {
        // "é" (2 bytes, valid) followed by a lone continuation byte: the
        // valid prefix must surface as soon as it completes, the dangling
        // byte only at finish().
        let mut d = StreamDecoder::default();
        assert_eq!(d.push(0xC3), "");
        assert_eq!(d.push(0xA9), "é");
        assert_eq!(d.push(0x80), ""); // continuation with no lead
        assert_eq!(d.finish(), "\u{FFFD}");
        // finish() on an empty decoder is a no-op.
        assert_eq!(d.finish(), "");
    }
}
