//! One builder for both stacks.
//!
//! The wall-clock stack ([`ChatAiStack`]) and the virtual-time stack
//! ([`SimStack`]) grew parallel configuration surfaces — `StackConfig`,
//! `SimStackConfig`, and a sprawl of `with_clock` / `with_seed` /
//! `with_engine_config` / `with_artifacts`-style knobs on the components
//! underneath. They describe the *same* deployment (cluster, replica
//! groups, scheduler tuning, engine tuning), differing only in which clock
//! drives it; keeping two hand-maintained copies of that description is how
//! a bench ends up measuring a config its paired test never ran.
//!
//! [`StackBuilder`] is the single description. Set the shared knobs once,
//! then pick the clock at the end:
//!
//! ```no_run
//! use chat_hpc::stack::StackBuilder;
//! use chat_hpc::scheduler::ServiceSpec;
//!
//! let b = StackBuilder::new()
//!     .with_services(vec![ServiceSpec::sim("intel-neural-7b", 1.0)])
//!     .with_seed(42);
//! let sim = b.build_sim();            // virtual time, deterministic
//! # let b2 = StackBuilder::new();
//! let real = b2.build().unwrap();     // wall clock, real sockets
//! ```
//!
//! Flavor-specific defaults stay flavor-specific: unless overridden,
//! `build()` keeps the wall-clock defaults (milliseconds-scaled cold
//! starts, 50 ms keepalive) and `build_sim()` keeps the virtual-time
//! defaults (realistic cold starts, 5 s keepalive) — virtual seconds are
//! free, so there is nothing to speed up. Knobs that only exist on one
//! side (SSH pool shape, shed/brownout watermarks) are reachable through
//! the [`StackBuilder::real_config`] / [`StackBuilder::sim_config`] escape
//! hatches, which return the fully-mapped config for further tweaking.

use std::time::Duration;

use anyhow::Result;

use crate::llmserver::EngineConfig;
use crate::scheduler::{SchedulerConfig, ServiceSpec};
use crate::slurm::ClusterSpec;
use crate::util::faults::FaultPlan;

use super::{ChatAiStack, SimStack, SimStackConfig, StackConfig};

/// Shared deployment description for [`ChatAiStack`] and [`SimStack`].
///
/// Every setter is chainable and optional; terminals are [`build`]
/// ([`ChatAiStack`], wall clock) and [`build_sim`] ([`SimStack`], virtual
/// time).
///
/// [`build`]: StackBuilder::build
/// [`build_sim`]: StackBuilder::build_sim
pub struct StackBuilder {
    cluster: ClusterSpec,
    /// Empty = the flavor's default single-service fleet.
    services: Vec<ServiceSpec>,
    scheduler: SchedulerConfig,
    engine: EngineConfig,
    seed: u64,
    /// `None` = flavor default (real 50 ms, sim 5 s).
    keepalive: Option<Duration>,
    /// `None` = flavor default (real 1e-3, sim 1.0).
    load_time_scale: Option<f64>,
    queue_timeout: Duration,
    dual_channel: bool,
    session_affinity: bool,
    with_external: bool,
    rate_limit_rps: Option<f64>,
    faults: FaultPlan,
}

impl Default for StackBuilder {
    fn default() -> StackBuilder {
        StackBuilder::new()
    }
}

impl StackBuilder {
    pub fn new() -> StackBuilder {
        StackBuilder {
            cluster: ClusterSpec::kisski(),
            services: Vec::new(),
            scheduler: SchedulerConfig::default(),
            engine: EngineConfig::default(),
            seed: 7,
            keepalive: None,
            load_time_scale: None,
            queue_timeout: Duration::from_secs(30),
            dual_channel: false,
            session_affinity: true,
            with_external: true,
            rate_limit_rps: None,
            faults: FaultPlan::new(),
        }
    }

    pub fn with_cluster(mut self, cluster: ClusterSpec) -> StackBuilder {
        self.cluster = cluster;
        self
    }

    /// Replace the fleet (one [`ServiceSpec`] per replica group / model).
    pub fn with_services(mut self, services: Vec<ServiceSpec>) -> StackBuilder {
        self.services = services;
        self
    }

    /// Append one replica group to the fleet.
    pub fn with_service(mut self, spec: ServiceSpec) -> StackBuilder {
        self.services.push(spec);
        self
    }

    pub fn with_scheduler(mut self, cfg: SchedulerConfig) -> StackBuilder {
        self.scheduler = cfg;
        self
    }

    /// Engine tuning applied to every instance core. The wall-clock stack
    /// threads the deployment-relevant subset (`abort_on_disconnect`,
    /// `prefill_chunk`, `prefix_cache`, `zero_copy_sse`); the sim stack
    /// takes the config whole.
    pub fn with_engine_config(mut self, cfg: EngineConfig) -> StackBuilder {
        self.engine = cfg;
        self
    }

    /// Root seed ([`SimStack`] only: wall-clock runs are not replayable).
    pub fn with_seed(mut self, seed: u64) -> StackBuilder {
        self.seed = seed;
        self
    }

    /// Scheduler tick / keepalive interval (paper: 5 s).
    pub fn with_keepalive(mut self, keepalive: Duration) -> StackBuilder {
        self.keepalive = Some(keepalive);
        self
    }

    /// Cold-start (weight-load) time scale: 1.0 = the paper's minutes-long
    /// 70B loads.
    pub fn with_load_time_scale(mut self, scale: f64) -> StackBuilder {
        self.load_time_scale = Some(scale);
        self
    }

    /// How long a request may wait for a routable instance (e.g. through a
    /// scale-from-zero cold start) before failing with `queue_timeout`.
    pub fn with_queue_timeout(mut self, timeout: Duration) -> StackBuilder {
        self.queue_timeout = timeout;
        self
    }

    pub fn with_dual_channel(mut self, on: bool) -> StackBuilder {
        self.dual_channel = on;
        self
    }

    /// Session-affine placement ([`SimStack`] honours this; the wall-clock
    /// interface reads the request's `session` key unconditionally).
    pub fn with_session_affinity(mut self, on: bool) -> StackBuilder {
        self.session_affinity = on;
        self
    }

    /// Also stand up the external GPT-4 wrapper route ([`ChatAiStack`]
    /// only).
    pub fn with_external(mut self, on: bool) -> StackBuilder {
        self.with_external = on;
        self
    }

    /// Per-user token-bucket rate limit at the gateway hop ([`SimStack`]
    /// only; the wall-clock gateway rate-limits per route).
    pub fn with_rate_limit_rps(mut self, rps: Option<f64>) -> StackBuilder {
        self.rate_limit_rps = rps;
        self
    }

    /// Deterministic fault schedule ([`SimStack`] only).
    pub fn with_faults(mut self, plan: FaultPlan) -> StackBuilder {
        self.faults = plan;
        self
    }

    /// Map onto a wall-clock [`StackConfig`] — the escape hatch for
    /// real-stack-only knobs (SSH pool shape, frame delays): tweak the
    /// returned config and pass it to [`ChatAiStack::start`] yourself.
    pub fn real_config(&self) -> StackConfig {
        let defaults = StackConfig::default();
        StackConfig {
            cluster: self.cluster.clone(),
            services: if self.services.is_empty() {
                defaults.services.clone()
            } else {
                self.services.clone()
            },
            load_time_scale: self.load_time_scale.unwrap_or(defaults.load_time_scale),
            keepalive: self.keepalive.unwrap_or(defaults.keepalive),
            queue_timeout: self.queue_timeout,
            with_external: self.with_external,
            dual_channel: self.dual_channel,
            abort_on_disconnect: self.engine.abort_on_disconnect,
            prefill_chunk: self.engine.prefill_chunk,
            prefix_cache: self.engine.prefix_cache,
            zero_copy_sse: self.engine.zero_copy_sse,
            scheduler: self.scheduler.clone(),
            ..defaults
        }
    }

    /// Map onto a virtual-time [`SimStackConfig`] — the escape hatch for
    /// sim-only knobs (shed/brownout watermarks, placement poll): tweak
    /// the returned config and pass it to [`SimStack::start`] yourself.
    pub fn sim_config(&self) -> SimStackConfig {
        let defaults = SimStackConfig::default();
        SimStackConfig {
            seed: self.seed,
            cluster: self.cluster.clone(),
            services: if self.services.is_empty() {
                defaults.services.clone()
            } else {
                self.services.clone()
            },
            load_time_scale: self.load_time_scale.unwrap_or(defaults.load_time_scale),
            keepalive: self.keepalive.unwrap_or(defaults.keepalive),
            queue_timeout: self.queue_timeout,
            rate_limit_rps: self.rate_limit_rps,
            engine: self.engine.clone(),
            scheduler: self.scheduler.clone(),
            dual_channel: self.dual_channel,
            faults: self.faults.clone(),
            session_affinity: self.session_affinity,
            ..defaults
        }
    }

    /// Start the wall-clock stack (real sockets, SSH sim, threads).
    pub fn build(self) -> Result<ChatAiStack> {
        ChatAiStack::start(self.real_config())
    }

    /// Start the virtual-time stack (discrete events, seed-replayable).
    pub fn build_sim(self) -> SimStack {
        SimStack::start(self.sim_config())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stack::SimRequest;

    #[test]
    fn flavor_defaults_survive_the_shared_description() {
        let b = StackBuilder::new();
        let real = b.real_config();
        let sim = b.sim_config();
        // The same untouched builder keeps each flavor's own scales.
        assert_eq!(real.load_time_scale, StackConfig::default().load_time_scale);
        assert_eq!(sim.load_time_scale, 1.0);
        assert_eq!(real.keepalive, Duration::from_millis(50));
        assert_eq!(sim.keepalive, Duration::from_secs(5));
        assert_eq!(real.queue_timeout, Duration::from_secs(30));
        assert_eq!(sim.queue_timeout, Duration::from_secs(30));
        assert_eq!(sim.seed, 7);
        assert!(sim.session_affinity);
        // Empty fleet = flavor default fleet.
        assert_eq!(real.services.len(), 1);
        assert_eq!(sim.services.len(), 1);
    }

    #[test]
    fn shared_knobs_reach_both_configs() {
        let b = StackBuilder::new()
            .with_seed(42)
            .with_keepalive(Duration::from_millis(100))
            .with_load_time_scale(0.25)
            .with_queue_timeout(Duration::from_secs(120))
            .with_dual_channel(true)
            .with_session_affinity(false)
            .with_engine_config(EngineConfig { prefix_cache: false, ..Default::default() });
        let real = b.real_config();
        let sim = b.sim_config();
        assert_eq!(real.keepalive, Duration::from_millis(100));
        assert_eq!(sim.keepalive, Duration::from_millis(100));
        assert_eq!(real.load_time_scale, 0.25);
        assert_eq!(sim.load_time_scale, 0.25);
        assert_eq!(real.queue_timeout, Duration::from_secs(120));
        assert_eq!(sim.queue_timeout, Duration::from_secs(120));
        assert!(real.dual_channel && sim.dual_channel);
        assert!(!real.prefix_cache);
        assert!(!sim.engine.prefix_cache);
        assert_eq!(sim.seed, 42);
        assert!(!sim.session_affinity);
    }

    #[test]
    fn builder_built_sim_replays_identically_to_direct_config() {
        let run = |via_builder: bool| {
            let stack = if via_builder {
                StackBuilder::new().with_seed(11).build_sim()
            } else {
                SimStack::start(SimStackConfig { seed: 11, ..Default::default() })
            };
            for i in 0..5u64 {
                stack.submit_chat_at(40_000_000 + i * 250_000, SimRequest::default());
            }
            assert!(stack.run_until_settled(Duration::from_secs(300)));
            stack.trace()
        };
        assert_eq!(
            run(true),
            run(false),
            "builder must describe exactly the config it replaces"
        );
    }
}
