//! Virtual-time full-stack harness (DESIGN.md §Virtual time).
//!
//! [`SimStack`] assembles the same serving path as [`super::ChatAiStack`] —
//! Slurm simulator, service scheduler, routing table, demand tracker,
//! per-instance engine cores, gateway rate limits — but drives all of it
//! single-threaded from a [`SimExecutor`]: every sleep, timeout and tick is
//! a scheduled event on the shared `SimClock`, and every engine decode step
//! charges its calibrated latency onto virtual time instead of sleeping.
//! A fig3-class day of traffic from thousands of users therefore runs in
//! seconds of CPU, and the entire run — placements, TTFTs, finish reasons,
//! autoscaling decisions, port numbers — is bit-identical for a fixed seed.
//!
//! What is simulated away relative to the wall-clock stack: the real HTTP
//! transport, the SSH framing and the gateway's header plumbing. Requests
//! enter at the gateway hop (per-user token-bucket rate limit + a fixed
//! ingress latency), are placed exactly like the cloud interface places
//! them (least-loaded routable instance, demand-tracker guard, deadline
//! budget burned by queue wait), and are served by real [`EngineCore`]s
//! running the real admission/prefill/decode loop over `SimBackend`'s
//! calibrated timing model. The scheduler, Slurm simulator and routing
//! table are the production objects, not mocks.
//!
//! Determinism contract: one scenario (same config, same seed, same
//! scheduled stimuli) produces byte-identical [`SimStack::trace`] output on
//! every run. `tests/sim_determinism.rs` pins this, and CI diffs two runs.

use std::cell::{Cell, RefCell};
use std::collections::{BTreeMap, BTreeSet};
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::gateway::TokenBucket;
use crate::llmserver::backend::SimBackend;
use crate::llmserver::{EngineConfig, EngineCore, GenEvent, GenRequest};
use crate::scheduler::routing::InflightGuard;
use crate::scheduler::{
    BackendKind, InstanceGuard, InstanceLauncher, SchedulerConfig, ServiceScheduler, ServiceSpec,
};
use crate::slurm::{ClusterSpec, JobId, JobSpec, SlurmSim};
use crate::util::clock::{Clock, SimClock};
use crate::util::faults::{FaultEvent, FaultPlan};
use crate::util::metrics::Registry;
use crate::util::rng::Rng;
use crate::util::sim::SimExecutor;

/// Virtual-time stack configuration. Unlike [`super::StackConfig`], load
/// times and model latencies default to *realistic* scales: virtual seconds
/// are free, so there is nothing to speed up.
pub struct SimStackConfig {
    /// Root seed: derives the placement RNG, per-request sampling seeds and
    /// the scheduler's port allocator. Same seed ⇒ same trace.
    pub seed: u64,
    pub cluster: ClusterSpec,
    pub services: Vec<ServiceSpec>,
    /// Cold-start scale in virtual time (1.0 = the paper's minutes-long
    /// 70B model loads).
    pub load_time_scale: f64,
    /// Scheduler tick period (the keepalive ping; paper: 5 s).
    pub keepalive: Duration,
    /// How long a request may wait for a routable instance before failing
    /// with `queue_timeout` (mirrors the cloud interface's queue budget).
    pub queue_timeout: Duration,
    /// Placement retry interval while no instance is routable.
    pub placement_poll: Duration,
    /// Fixed ingress latency between gateway arrival and placement.
    pub gateway_latency: Duration,
    /// Per-user token-bucket rate limit at the gateway hop (None = off).
    pub rate_limit_rps: Option<f64>,
    /// Engine tuning applied to every instance core.
    pub engine: EngineConfig,
    pub scheduler: SchedulerConfig,
    /// Dual-channel streaming flag, mirroring `StackConfig::dual_channel`.
    /// The virtual-time harness simulates the SSH transport away, so this
    /// MUST be trace-neutral: the same seed produces a byte-identical
    /// trace whether it is set or not (CI pins that by running the
    /// determinism suite with it enabled). It is surfaced through the
    /// `sim_dual_channel` gauge only — metrics are not part of the trace.
    pub dual_channel: bool,
    /// Deterministic fault schedule applied on the virtual clock
    /// (DESIGN.md §Failure policy). Applied events fold `fault …` lines
    /// into [`SimStack::trace`]; an *empty* plan is contractually
    /// invisible — byte-identical traces to a build without this field.
    pub faults: FaultPlan,
    /// Admission watermark: an arriving request is refused with reason
    /// `shed_overload` when more than this many requests are already open
    /// at the gateway (0 = shedding off).
    pub shed_watermark: u32,
    /// Brownout watermark: above this many open requests, arriving
    /// requests have `max_tokens` clamped to `brownout_max_tokens`
    /// (0 = brownout off).
    pub brownout_watermark: u32,
    /// The degraded token budget handed out under brownout.
    pub brownout_max_tokens: usize,
    /// Session-affine placement: route a request to the replica whose
    /// prefix cache most likely holds its conversation (rendezvous hash on
    /// the session id, load-aware spill). Off ⇒ the seed behaviour,
    /// least-loaded placement for every request.
    pub session_affinity: bool,
}

/// How far above the least-loaded replica the affinity target may run
/// before a request spills to least-loaded placement instead (in in-flight
/// requests). Small: a hot home loses its cache win to queueing long
/// before this many requests stack up.
const AFFINITY_SPILL_MARGIN: i64 = 2;

impl Default for SimStackConfig {
    fn default() -> SimStackConfig {
        SimStackConfig {
            seed: 7,
            cluster: ClusterSpec::kisski(),
            services: vec![ServiceSpec::sim("intel-neural-7b", 1.0)],
            load_time_scale: 1.0,
            keepalive: Duration::from_secs(5),
            queue_timeout: Duration::from_secs(30),
            placement_poll: Duration::from_millis(20),
            gateway_latency: Duration::from_millis(1),
            rate_limit_rps: None,
            engine: EngineConfig::default(),
            scheduler: SchedulerConfig::default(),
            dual_channel: false,
            faults: FaultPlan::new(),
            shed_watermark: 0,
            brownout_watermark: 0,
            brownout_max_tokens: 8,
            session_affinity: true,
        }
    }
}

/// One chat request entering at the gateway.
#[derive(Debug, Clone)]
pub struct SimRequest {
    pub user: String,
    pub model: String,
    /// Conversation id the affinity hash keys on (multi-turn chats reuse
    /// one id across turns). `None` falls back to the user id, so a user's
    /// turns still share a home replica.
    pub session: Option<String>,
    pub prompt: String,
    pub max_tokens: usize,
    /// End-to-end deadline budget in ms (queue wait counts toward it).
    pub deadline_ms: Option<u64>,
}

impl Default for SimRequest {
    fn default() -> SimRequest {
        SimRequest {
            user: "user-0".into(),
            model: "intel-neural-7b".into(),
            session: None,
            prompt: "hello".into(),
            max_tokens: 16,
            deadline_ms: None,
        }
    }
}

/// Per-request outcome, one per submitted request.
#[derive(Debug, Clone)]
pub struct SimRecord {
    pub id: u64,
    pub user: String,
    pub model: String,
    /// Virtual-us the request arrived at the gateway.
    pub submit_us: u64,
    /// Instance job the request was placed on (None if it never placed:
    /// rate-limited, queue timeout, pre-placement deadline or cancel).
    pub placed_job: Option<JobId>,
    /// Time to first token in virtual us (None if no token was produced).
    pub ttft_us: Option<u64>,
    pub finish_us: u64,
    /// Engine finish reason ("stop", "length", "deadline", "cancelled",
    /// "kv_exhausted"), a gateway/placement outcome ("rate_limited",
    /// "queue_timeout", "client_disconnect"), or "error: …".
    pub finish_reason: String,
    pub prompt_tokens: usize,
    pub completion_tokens: usize,
    pub cached_tokens: usize,
}

impl SimRecord {
    /// One deterministic trace line (the seed-replay currency).
    pub fn trace_line(&self) -> String {
        format!(
            "req={} user={} model={} submit_us={} job={} ttft_us={} finish_us={} \
             reason={} prompt={} completion={} cached={}",
            self.id,
            self.user,
            self.model,
            self.submit_us,
            self.placed_job.map(|j| j.to_string()).unwrap_or_else(|| "-".into()),
            self.ttft_us.map(|t| t.to_string()).unwrap_or_else(|| "-".into()),
            self.finish_us,
            self.finish_reason,
            self.prompt_tokens,
            self.completion_tokens,
            self.cached_tokens,
        )
    }
}

// ---------------------------------------------------------------------------
// Instance launcher: engine cores stepped inline instead of engine threads
// ---------------------------------------------------------------------------

/// The virtual-time [`InstanceLauncher`]: each launched job is an
/// [`EngineCore`] the event loop steps inline, plus a ready-at timestamp
/// standing in for the cold-start model load (the port stays "unbound" —
/// probes fail — until virtual time passes it, exactly like
/// `RealLauncher`'s delayed bind).
struct SimLauncher {
    clock: Arc<SimClock>,
    metrics: Registry,
    load_time_scale: f64,
    engine_cfg: EngineConfig,
    instances: Mutex<BTreeMap<JobId, Arc<SimInstance>>>,
    /// Gray-slow nodes: hostname -> slowdown factor × 1000. Applied to
    /// every live instance on the node and to later launches there, so a
    /// replacement replica placed on a still-gray node starts slow too.
    gray: Mutex<BTreeMap<String, u64>>,
    /// Every modeled weight load, in launch order — folded into
    /// [`SimStack::trace`] as `load …` lines so seed-replay pins the
    /// cold-start accounting, not just request outcomes.
    loads: Mutex<Vec<LoadRecord>>,
}

/// One modeled weight load (cold start) charged onto virtual time.
struct LoadRecord {
    job: JobId,
    service: String,
    start_us: u64,
    ready_us: u64,
}

struct SimInstance {
    addr: String,
    node: String,
    ready_at_us: u64,
    /// This instance's backend gray-failure dial (1000 = healthy).
    slowdown: Arc<AtomicU64>,
    core: Mutex<EngineCore>,
}

impl SimLauncher {
    fn instance(&self, job_id: JobId) -> Option<Arc<SimInstance>> {
        self.instances.lock().unwrap().get(&job_id).cloned()
    }

    /// Degrade every instance on `node` (and future launches there) to
    /// `factor_milli`/1000 × its calibrated compute cost. Probes still
    /// pass: that is the point of a gray failure.
    fn set_gray(&self, node: &str, factor_milli: u64) {
        self.gray.lock().unwrap().insert(node.to_string(), factor_milli);
        for si in self.instances.lock().unwrap().values() {
            if si.node == node {
                si.slowdown.store(factor_milli, Ordering::Relaxed);
            }
        }
    }

    fn clear_gray(&self, node: &str) {
        self.gray.lock().unwrap().remove(node);
        for si in self.instances.lock().unwrap().values() {
            if si.node == node {
                si.slowdown.store(1000, Ordering::Relaxed);
            }
        }
    }
}

impl InstanceLauncher for SimLauncher {
    fn launch(&self, job_id: JobId, service: &ServiceSpec, node: &str, port: u16) {
        let (backend, load_secs) = match &service.backend {
            BackendKind::Sim { profile, time_scale } => {
                let Some(b) = SimBackend::by_name(profile, *time_scale) else {
                    crate::log_warn!("simstack", "unknown profile {profile}");
                    return;
                };
                let load = crate::llmserver::SimProfile::by_name(profile)
                    .map(|p| p.load_secs)
                    .unwrap_or(10.0);
                (b.with_clock(self.clock.clone()), load)
            }
            BackendKind::Pjrt { model } => {
                // The AOT PJRT path computes on real hardware: it cannot
                // charge virtual time. Leave the job perpetually unready.
                crate::log_warn!("simstack", "pjrt model {model} unsupported under virtual time");
                return;
            }
        };
        let slowdown = backend.slowdown_handle();
        if let Some(factor) = self.gray.lock().unwrap().get(node) {
            slowdown.store(*factor, Ordering::Relaxed);
        }
        let core = EngineCore::new(
            Box::new(backend),
            self.engine_cfg.clone(),
            self.metrics.clone(),
            self.clock.clone(),
        );
        let now = self.clock.now_us();
        let ready_at_us = now.saturating_add((load_secs * self.load_time_scale * 1e6) as u64);
        self.metrics
            .counter("launcher_model_load_total", &[("service", &service.name)])
            .inc();
        self.loads.lock().unwrap().push(LoadRecord {
            job: job_id,
            service: service.name.clone(),
            start_us: now,
            ready_us: ready_at_us,
        });
        self.instances.lock().unwrap().insert(
            job_id,
            Arc::new(SimInstance {
                addr: format!("127.0.0.1:{port}"),
                node: node.to_string(),
                ready_at_us,
                slowdown,
                core: Mutex::new(core),
            }),
        );
    }

    fn terminate(&self, job_id: JobId) {
        if let Some(si) = self.instances.lock().unwrap().remove(&job_id) {
            // Fails all in-flight and queued work with "engine stopped";
            // the keepalive sweep turns those into error records.
            si.core.lock().unwrap().shutdown();
        }
    }

    fn probe(&self, addr: &str) -> bool {
        let now = self.clock.now_us();
        self.instances
            .lock()
            .unwrap()
            .values()
            .any(|si| si.addr == addr && now >= si.ready_at_us)
    }
}

// ---------------------------------------------------------------------------
// The stack
// ---------------------------------------------------------------------------

struct Inflight {
    user: String,
    model: String,
    job_id: JobId,
    submit_us: u64,
    rx: Receiver<GenEvent>,
    _demand: InflightGuard,
    _load: InstanceGuard,
}

struct PendingReq {
    id: u64,
    user: String,
    model: String,
    session: Option<String>,
    prompt: String,
    max_tokens: usize,
    deadline_ms: Option<u64>,
    submit_us: u64,
    /// Demand guard held from gateway arrival (matching the cloud
    /// interface): a scale-from-zero group must see demand while the
    /// request is still queued, not only once placement succeeds —
    /// otherwise nothing ever wakes a 0-instance model.
    demand: InflightGuard,
}

struct SimInner {
    clock: Arc<SimClock>,
    metrics: Registry,
    slurm: Arc<Mutex<SlurmSim>>,
    scheduler: Arc<ServiceScheduler>,
    launcher: Arc<SimLauncher>,
    root_seed: u64,
    keepalive: Duration,
    queue_timeout_us: u64,
    placement_poll: Duration,
    gateway_latency: Duration,
    rate_limit_rps: Option<f64>,
    route_rng: RefCell<Rng>,
    buckets: RefCell<BTreeMap<String, TokenBucket>>,
    inflight: RefCell<BTreeMap<u64, Inflight>>,
    /// Secondary index: which in-flight requests ride which instance.
    by_job: RefCell<BTreeMap<JobId, Vec<u64>>>,
    /// Instances with a pump event already scheduled (no duplicates).
    pumping: RefCell<BTreeSet<JobId>>,
    /// Client cancels that arrived before their request placed.
    cancelled: RefCell<BTreeSet<u64>>,
    records: RefCell<Vec<SimRecord>>,
    next_id: Cell<u64>,
    /// Submitted-but-unfinished requests (drives `run_until_settled`).
    open: Cell<u64>,
    // --- Fault plane + admission control (DESIGN.md §Failure policy) ---
    /// Proxy↔cluster link state: while down, token pumps park in
    /// `deferred_pumps` (streams freeze) instead of stepping engines.
    link_down: Cell<bool>,
    /// Pumps parked by a link outage, re-armed on `LinkUp`.
    deferred_pumps: RefCell<BTreeSet<JobId>>,
    /// Placement outage: `try_place` keeps polling (and burning queue /
    /// deadline budgets) without reaching any instance.
    upstream_down: Cell<bool>,
    /// Requests past the gateway hop and not yet finished — the load
    /// signal the shed and brownout watermarks compare against.
    active: Cell<u64>,
    shed_watermark: u32,
    brownout_watermark: u32,
    brownout_max_tokens: usize,
    /// Applied fault events, folded into `trace()` after the records.
    fault_log: RefCell<Vec<String>>,
    /// Session-affine placement toggle (`SimStackConfig::session_affinity`).
    session_affinity: bool,
}

/// The discrete-event serving stack. Schedule stimuli (`submit_chat_at`,
/// `cancel_at`, `fail_node_at`), run virtual time forward, read the trace.
pub struct SimStack {
    exec: Rc<SimExecutor>,
    inner: Rc<SimInner>,
}

impl SimStack {
    /// Start from a raw [`SimStackConfig`]. Prefer
    /// [`crate::stack::StackBuilder`] for new code — it shares one
    /// deployment description with [`super::ChatAiStack`]; this remains
    /// the underlying entry point (and the escape hatch for sim-only
    /// knobs like the shed/brownout watermarks).
    pub fn start(cfg: SimStackConfig) -> SimStack {
        let exec = Rc::new(SimExecutor::new(cfg.seed));
        let clock = exec.clock();
        let metrics = Registry::new();
        // Trace-neutral by contract (see `SimStackConfig::dual_channel`).
        metrics.gauge("sim_dual_channel", &[]).set(cfg.dual_channel as i64);
        let slurm = Arc::new(Mutex::new(SlurmSim::new(cfg.cluster.clone())));
        let launcher = Arc::new(SimLauncher {
            clock: clock.clone(),
            metrics: metrics.clone(),
            load_time_scale: cfg.load_time_scale,
            engine_cfg: cfg.engine.clone(),
            instances: Mutex::new(BTreeMap::new()),
            gray: Mutex::new(BTreeMap::new()),
            loads: Mutex::new(Vec::new()),
        });
        let scheduler = Arc::new(
            ServiceScheduler::new(
                slurm.clone(),
                clock.clone(),
                launcher.clone(),
                cfg.services.clone(),
                cfg.scheduler.clone(),
                metrics.clone(),
            )
            // Pin the port allocator: two runs of one scenario must place
            // jobs on byte-identical (node, port) pairs.
            .with_seed(cfg.seed ^ 0x5EED_0001),
        );
        let route_rng = exec.rng("placement");
        let inner = Rc::new(SimInner {
            clock,
            metrics,
            slurm,
            scheduler,
            launcher,
            root_seed: cfg.seed,
            keepalive: cfg.keepalive.max(Duration::from_micros(1)),
            queue_timeout_us: cfg.queue_timeout.as_micros() as u64,
            placement_poll: cfg.placement_poll.max(Duration::from_micros(1)),
            gateway_latency: cfg.gateway_latency,
            rate_limit_rps: cfg.rate_limit_rps,
            route_rng: RefCell::new(route_rng),
            buckets: RefCell::new(BTreeMap::new()),
            inflight: RefCell::new(BTreeMap::new()),
            by_job: RefCell::new(BTreeMap::new()),
            pumping: RefCell::new(BTreeSet::new()),
            cancelled: RefCell::new(BTreeSet::new()),
            records: RefCell::new(Vec::new()),
            next_id: Cell::new(1),
            open: Cell::new(0),
            link_down: Cell::new(false),
            deferred_pumps: RefCell::new(BTreeSet::new()),
            upstream_down: Cell::new(false),
            active: Cell::new(0),
            shed_watermark: cfg.shed_watermark,
            brownout_watermark: cfg.brownout_watermark,
            brownout_max_tokens: cfg.brownout_max_tokens,
            fault_log: RefCell::new(Vec::new()),
            session_affinity: cfg.session_affinity,
        });
        // Boot: the first scheduler pass (t = 0) submits min_instances.
        {
            let inner2 = inner.clone();
            exec.schedule_at_us(0, move |ex| keepalive(&inner2, ex));
        }
        // Schedule the fault plan. An empty plan schedules nothing — the
        // trace-neutrality contract (`SimStackConfig::faults`).
        for tf in cfg.faults.events() {
            let inner2 = inner.clone();
            let event = tf.event.clone();
            exec.schedule_at_us(tf.at_us, move |ex| apply_fault(&inner2, ex, &event));
        }
        SimStack { exec, inner }
    }

    pub fn clock(&self) -> Arc<SimClock> {
        self.inner.clock.clone()
    }

    pub fn now_us(&self) -> u64 {
        self.inner.clock.now_us()
    }

    pub fn metrics(&self) -> Registry {
        self.inner.metrics.clone()
    }

    pub fn scheduler(&self) -> Arc<ServiceScheduler> {
        self.inner.scheduler.clone()
    }

    pub fn slurm(&self) -> Arc<Mutex<SlurmSim>> {
        self.inner.slurm.clone()
    }

    /// Events executed so far (throughput telemetry for benches).
    pub fn executed_events(&self) -> u64 {
        self.exec.executed()
    }

    /// Schedule a chat request to arrive at absolute virtual time `at_us`.
    /// Returns the request id its [`SimRecord`] will carry.
    pub fn submit_chat_at(&self, at_us: u64, req: SimRequest) -> u64 {
        let id = self.inner.next_id.get();
        self.inner.next_id.set(id + 1);
        self.inner.open.set(self.inner.open.get() + 1);
        let inner = self.inner.clone();
        self.exec.schedule_at_us(at_us, move |ex| arrive(&inner, ex, id, req));
        id
    }

    /// Schedule a client disconnect for request `id` at `at_us`: the
    /// engine frees its batch slot within one decode step, and the record
    /// finishes with reason `client_disconnect`.
    pub fn cancel_at(&self, id: u64, at_us: u64) {
        let inner = self.inner.clone();
        self.exec.schedule_at_us(at_us, move |_| {
            let removed = inner.inflight.borrow_mut().remove(&id);
            match removed {
                Some(fl) => {
                    unindex(&inner, fl.job_id, id);
                    let now = inner.clock.now_us();
                    record(
                        &inner,
                        SimRecord {
                            id,
                            user: fl.user.clone(),
                            model: fl.model.clone(),
                            submit_us: fl.submit_us,
                            placed_job: Some(fl.job_id),
                            ttft_us: None,
                            finish_us: now,
                            finish_reason: "client_disconnect".into(),
                            prompt_tokens: 0,
                            completion_tokens: 0,
                            cached_tokens: 0,
                        },
                    );
                    // Dropping `fl` drops its rx: the engine's next send
                    // fails and the slot frees with "cancelled".
                }
                None => {
                    // Not placed yet (or already finished): flag it so the
                    // placement retry gives up instead of submitting.
                    inner.cancelled.borrow_mut().insert(id);
                }
            }
        });
    }

    /// Schedule a node failure: its jobs die, and the next scheduler tick
    /// reconciles (decommission + replacement submission).
    pub fn fail_node_at(&self, node: &str, at_us: u64) {
        let inner = self.inner.clone();
        let node = node.to_string();
        self.exec.schedule_at_us(at_us, move |_| {
            let now = inner.clock.now_us();
            inner.slurm.lock().unwrap().fail_node(&node, now);
        });
    }

    pub fn restore_node_at(&self, node: &str, at_us: u64) {
        let inner = self.inner.clone();
        let node = node.to_string();
        self.exec.schedule_at_us(at_us, move |_| {
            inner.slurm.lock().unwrap().restore_node(&node);
        });
    }

    /// Run every event due up to absolute virtual time `until_us`.
    pub fn run_until_us(&self, until_us: u64) {
        self.exec.run_until_us(until_us);
    }

    /// Run virtual time forward by `d`.
    pub fn run_for(&self, d: Duration) {
        self.exec.run_for(d);
    }

    /// Run until every submitted request has a record, or until `horizon`
    /// of virtual time passes — whichever first. Returns `true` when all
    /// requests settled.
    pub fn run_until_settled(&self, horizon: Duration) -> bool {
        let deadline =
            self.inner.clock.now_us().saturating_add(horizon.as_micros() as u64);
        while self.inner.open.get() > 0 {
            match self.exec.next_due_us() {
                Some(t) if t <= deadline => {
                    self.exec.step();
                }
                _ => break,
            }
        }
        self.inner.open.get() == 0
    }

    /// Requests submitted but not yet finished.
    pub fn open_requests(&self) -> u64 {
        self.inner.open.get()
    }

    pub fn records(&self) -> Vec<SimRecord> {
        self.inner.records.borrow().clone()
    }

    /// The deterministic per-request event trace, sorted by request id —
    /// the artifact seed-replay tests and CI byte-compare.
    pub fn trace(&self) -> String {
        let mut recs = self.records();
        recs.sort_by_key(|r| r.id);
        let mut out = String::new();
        for r in &recs {
            out.push_str(&r.trace_line());
            out.push('\n');
        }
        // Applied faults are part of the canonical trace: a replay must
        // reproduce the failure schedule, not just the request outcomes.
        // With no faults applied this appends nothing — traces stay
        // byte-identical to a fault-free build.
        for line in self.inner.fault_log.borrow().iter() {
            out.push_str(line);
            out.push('\n');
        }
        // Modeled weight loads close the trace: replaying a seed must
        // reproduce the cold-start schedule (which job loaded which model,
        // and how long the load took) byte-for-byte.
        for l in self.inner.launcher.loads.lock().unwrap().iter() {
            out.push_str(&format!(
                "load job={} service={} start_us={} ready_us={}\n",
                l.job, l.service, l.start_us, l.ready_us
            ));
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Event bodies
// ---------------------------------------------------------------------------

/// The scheduler tick: exactly what the SSH keepalive ping triggers in the
/// wall-clock stack, plus a sweep for requests whose instance died since
/// the last tick (their channels already hold the error).
fn keepalive(inner: &Rc<SimInner>, ex: &SimExecutor) {
    inner.scheduler.run_once();
    let ids: Vec<u64> = inner.inflight.borrow().keys().cloned().collect();
    for id in ids {
        drain_one(inner, id);
    }
    let inner2 = inner.clone();
    ex.schedule_in(inner.keepalive, move |ex| keepalive(&inner2, ex));
}

/// Gateway ingress: rate limit, then forward to placement after the hop
/// latency.
fn arrive(inner: &Rc<SimInner>, ex: &SimExecutor, id: u64, req: SimRequest) {
    let now = inner.clock.now_us();
    // Count this request toward gateway load from arrival to its record;
    // `record()` is the single finish funnel, so the decrement is exact.
    inner.active.set(inner.active.get() + 1);
    if let Some(rps) = inner.rate_limit_rps {
        let allowed = {
            let mut buckets = inner.buckets.borrow_mut();
            let clock: Arc<dyn Clock> = inner.clock.clone();
            buckets
                .entry(req.user.clone())
                .or_insert_with(|| TokenBucket::new(rps.max(1.0), rps, clock))
                .try_take()
        };
        if !allowed {
            record(
                inner,
                SimRecord {
                    id,
                    user: req.user,
                    model: req.model,
                    submit_us: now,
                    placed_job: None,
                    ttft_us: None,
                    finish_us: now,
                    finish_reason: "rate_limited".into(),
                    prompt_tokens: 0,
                    completion_tokens: 0,
                    cached_tokens: 0,
                },
            );
            return;
        }
    }
    // Load shedding: refuse outright above the watermark — a fast 503 is
    // kinder than queueing a request that will time out anyway.
    if inner.shed_watermark > 0 && inner.active.get() > inner.shed_watermark as u64 {
        inner.metrics.counter("sim_shed_total", &[]).inc();
        record(
            inner,
            SimRecord {
                id,
                user: req.user,
                model: req.model,
                submit_us: now,
                placed_job: None,
                ttft_us: None,
                finish_us: now,
                finish_reason: "shed_overload".into(),
                prompt_tokens: 0,
                completion_tokens: 0,
                cached_tokens: 0,
            },
        );
        return;
    }
    // Brownout: past the (lower) watermark, admit but clamp the token
    // budget so every accepted request stays cheap.
    let mut max_tokens = req.max_tokens;
    if inner.brownout_watermark > 0
        && inner.active.get() > inner.brownout_watermark as u64
        && max_tokens > inner.brownout_max_tokens
    {
        max_tokens = inner.brownout_max_tokens;
        inner.metrics.counter("sim_brownout_total", &[]).inc();
    }
    // Admitted: signal demand NOW, so a scale-from-zero replica group sees
    // the queued request and wakes. The guard rides the pending request
    // through placement retries and into its in-flight record.
    let demand = inner.scheduler.demand.begin(&req.model);
    let p = PendingReq {
        id,
        user: req.user,
        model: req.model,
        session: req.session,
        prompt: req.prompt,
        max_tokens,
        deadline_ms: req.deadline_ms,
        submit_us: now,
        demand,
    };
    if inner.gateway_latency.is_zero() {
        try_place(inner, ex, p);
    } else {
        let inner2 = inner.clone();
        ex.schedule_in(inner.gateway_latency, move |ex| try_place(&inner2, ex, p));
    }
}

/// Placement: the cloud interface's loop — least-loaded routable instance,
/// demand guard, deadline budget burned by the wait — as retried events.
fn try_place(inner: &Rc<SimInner>, ex: &SimExecutor, p: PendingReq) {
    if inner.cancelled.borrow_mut().remove(&p.id) {
        finish_unplaced(inner, &p, "client_disconnect");
        return;
    }
    let now = inner.clock.now_us();
    let waited_us = now.saturating_sub(p.submit_us);
    if let Some(ms) = p.deadline_ms {
        if waited_us >= ms.saturating_mul(1000) {
            finish_unplaced(inner, &p, "deadline");
            return;
        }
    }
    if waited_us >= inner.queue_timeout_us {
        finish_unplaced(inner, &p, "queue_timeout");
        return;
    }
    // Placement outage: every upstream unreachable. Keep polling — the
    // deadline and queue-timeout checks above still burn the budget, so a
    // long enough outage fails queued requests exactly like a real one.
    if inner.upstream_down.get() {
        retry_place(inner, ex, p);
        return;
    }
    let pick = {
        let mut rng = inner.route_rng.borrow_mut();
        if inner.session_affinity {
            let key = p.session.as_deref().unwrap_or(&p.user);
            inner
                .scheduler
                .routing
                .pick_affine(&p.model, key, AFFINITY_SPILL_MARGIN, &mut rng)
        } else {
            inner.scheduler.routing.pick_least_loaded(&p.model, &mut rng).map(|i| (i, false))
        }
    };
    let Some((target, affine_hit)) = pick else {
        retry_place(inner, ex, p);
        return;
    };
    let Some(si) = inner.launcher.instance(target.job_id) else {
        retry_place(inner, ex, p);
        return;
    };
    if affine_hit {
        inner
            .metrics
            .counter("sched_affinity_hits_total", &[("service", &p.model)])
            .inc();
    }
    let load = inner.scheduler.routing.begin_request(target.job_id);
    // Forward the *remaining* budget: transit and queue wait count.
    let remaining_ms = p.deadline_ms.map(|ms| ms.saturating_sub(waited_us / 1000));
    let (tx, rx) = channel();
    si.core.lock().unwrap().submit(
        GenRequest {
            prompt: p.prompt,
            max_tokens: p.max_tokens,
            temperature: 0.0,
            top_k: 0,
            seed: inner.root_seed ^ p.id,
            deadline_ms: remaining_ms,
        },
        tx,
    );
    inner.inflight.borrow_mut().insert(
        p.id,
        Inflight {
            user: p.user,
            model: p.model,
            job_id: target.job_id,
            submit_us: p.submit_us,
            rx,
            _demand: p.demand,
            _load: load,
        },
    );
    inner.by_job.borrow_mut().entry(target.job_id).or_default().push(p.id);
    ensure_pump(inner, ex, target.job_id);
}

fn retry_place(inner: &Rc<SimInner>, ex: &SimExecutor, p: PendingReq) {
    let inner2 = inner.clone();
    ex.schedule_in(inner.placement_poll, move |ex| try_place(&inner2, ex, p));
}

/// Schedule a pump event for an instance unless one is already pending.
fn ensure_pump(inner: &Rc<SimInner>, ex: &SimExecutor, job_id: JobId) {
    if !inner.pumping.borrow_mut().insert(job_id) {
        return;
    }
    let inner2 = inner.clone();
    ex.schedule_in(Duration::ZERO, move |ex| pump(&inner2, ex, job_id));
}

/// One engine iteration for one instance. The backend charge advances the
/// clock during `step()`, so the follow-up pump lands one step-duration
/// later in virtual time — the decode cadence, without threads.
fn pump(inner: &Rc<SimInner>, ex: &SimExecutor, job_id: JobId) {
    inner.pumping.borrow_mut().remove(&job_id);
    if inner.link_down.get() {
        // Link outage: park the pump instead of stepping the engine. The
        // stream freezes mid-flight and resumes where it left off when
        // `LinkUp` re-arms every deferred pump.
        inner.deferred_pumps.borrow_mut().insert(job_id);
        return;
    }
    let Some(si) = inner.launcher.instance(job_id) else {
        // Decommissioned since this pump was scheduled: its channels were
        // answered by shutdown(); collect the errors.
        drain_job(inner, job_id);
        return;
    };
    let idle_after = {
        let mut core = si.core.lock().unwrap();
        if core.is_idle() {
            true
        } else {
            core.step();
            core.is_idle()
        }
    };
    drain_job(inner, job_id);
    if !idle_after {
        ensure_pump(inner, ex, job_id);
    }
}

/// Drain finished generations for every request riding `job_id`.
fn drain_job(inner: &Rc<SimInner>, job_id: JobId) {
    let ids = inner.by_job.borrow().get(&job_id).cloned().unwrap_or_default();
    for id in ids {
        drain_one(inner, id);
    }
}

/// Poll one in-flight request's event channel; finalize on Done/Error.
fn drain_one(inner: &Rc<SimInner>, id: u64) {
    let outcome = {
        let mut map = inner.inflight.borrow_mut();
        let Some(fl) = map.get_mut(&id) else { return };
        let mut terminal = None;
        loop {
            match fl.rx.try_recv() {
                Ok(GenEvent::Token(_)) => {}
                Ok(GenEvent::Done(usage)) => {
                    terminal = Some(Ok(usage));
                    break;
                }
                Ok(GenEvent::Error(e)) => {
                    terminal = Some(Err(e));
                    break;
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    terminal = Some(Err("engine dropped the generation".into()));
                    break;
                }
            }
        }
        terminal.map(|t| (map.remove(&id).unwrap(), t))
    };
    let Some((fl, result)) = outcome else { return };
    unindex(inner, fl.job_id, id);
    let now = inner.clock.now_us();
    let rec = match result {
        Ok(u) => SimRecord {
            id,
            user: fl.user.clone(),
            model: fl.model.clone(),
            submit_us: fl.submit_us,
            placed_job: Some(fl.job_id),
            ttft_us: (u.completion_tokens > 0).then(|| u.ttft.as_micros() as u64),
            finish_us: now,
            finish_reason: u.finish_reason.to_string(),
            prompt_tokens: u.prompt_tokens,
            completion_tokens: u.completion_tokens,
            cached_tokens: u.cached_tokens,
        },
        Err(e) => SimRecord {
            id,
            user: fl.user.clone(),
            model: fl.model.clone(),
            submit_us: fl.submit_us,
            placed_job: Some(fl.job_id),
            ttft_us: None,
            finish_us: now,
            finish_reason: format!("error: {e}"),
            prompt_tokens: 0,
            completion_tokens: 0,
            cached_tokens: 0,
        },
    };
    record(inner, rec);
}

fn finish_unplaced(inner: &Rc<SimInner>, p: &PendingReq, reason: &str) {
    let now = inner.clock.now_us();
    record(
        inner,
        SimRecord {
            id: p.id,
            user: p.user.clone(),
            model: p.model.clone(),
            submit_us: p.submit_us,
            placed_job: None,
            ttft_us: None,
            finish_us: now,
            finish_reason: reason.to_string(),
            prompt_tokens: 0,
            completion_tokens: 0,
            cached_tokens: 0,
        },
    );
}

fn record(inner: &Rc<SimInner>, rec: SimRecord) {
    inner.open.set(inner.open.get().saturating_sub(1));
    inner.active.set(inner.active.get().saturating_sub(1));
    inner.records.borrow_mut().push(rec);
}

fn unindex(inner: &Rc<SimInner>, job_id: JobId, id: u64) {
    let mut by_job = inner.by_job.borrow_mut();
    if let Some(v) = by_job.get_mut(&job_id) {
        v.retain(|&x| x != id);
        if v.is_empty() {
            by_job.remove(&job_id);
        }
    }
}

/// Apply one scheduled [`FaultEvent`] and fold it into the canonical
/// trace. Everything here runs on the virtual clock, so a plan replays
/// bit-identically under the same seed.
fn apply_fault(inner: &Rc<SimInner>, ex: &SimExecutor, event: &FaultEvent) {
    let now = inner.clock.now_us();
    inner.fault_log.borrow_mut().push(format!("fault at_us={now} {}", event.trace_tag()));
    inner.metrics.counter("sim_faults_applied_total", &[]).inc();
    match event {
        FaultEvent::NodeFail { node } => {
            inner.slurm.lock().unwrap().fail_node(node, now);
        }
        FaultEvent::NodeRestore { node } => {
            inner.slurm.lock().unwrap().restore_node(node);
        }
        FaultEvent::PreemptionStorm { jobs, gpus_per_job, walltime } => {
            // A burst of batch work above the scavenger tier (priority 10
            // sits between scavenger −10 and guaranteed 100): Slurm's
            // backfill grants it scavenger allocations after GraceTime.
            let mut slurm = inner.slurm.lock().unwrap();
            for i in 0..*jobs {
                slurm.sbatch(
                    JobSpec {
                        name: format!("storm-{i}"),
                        account: "storm".into(),
                        gpus_per_node: *gpus_per_job,
                        time_limit: *walltime,
                        priority: 10,
                        duration: Some(*walltime),
                        ..Default::default()
                    },
                    now,
                );
            }
        }
        FaultEvent::LinkDown => inner.link_down.set(true),
        FaultEvent::LinkUp => {
            inner.link_down.set(false);
            let deferred = std::mem::take(&mut *inner.deferred_pumps.borrow_mut());
            for job_id in deferred {
                ensure_pump(inner, ex, job_id);
            }
        }
        FaultEvent::GraySlow { node, factor_milli } => {
            inner.launcher.set_gray(node, *factor_milli);
        }
        FaultEvent::GrayRecover { node } => inner.launcher.clear_gray(node),
        FaultEvent::UpstreamDown => inner.upstream_down.set(true),
        FaultEvent::UpstreamUp => inner.upstream_down.set(false),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_chat_requests_deterministically_under_virtual_time() {
        let run = || {
            let stack = SimStack::start(SimStackConfig { seed: 11, ..Default::default() });
            // Cold start: job submitted at t=0, launched on the next tick,
            // ready after the 30 s simulated model load. Arrive after that.
            for i in 0..5u64 {
                stack.submit_chat_at(
                    40_000_000 + i * 250_000,
                    SimRequest {
                        user: format!("user-{i}"),
                        prompt: format!("hello from user {i}"),
                        max_tokens: 8,
                        ..Default::default()
                    },
                );
            }
            assert!(
                stack.run_until_settled(Duration::from_secs(600)),
                "all requests settle within the horizon"
            );
            stack.trace()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same seed + same scenario => byte-identical traces");
        assert_eq!(a.lines().filter(|l| l.starts_with("req=")).count(), 5);
        for line in a.lines().filter(|l| l.starts_with("req=")) {
            assert!(
                line.contains("reason=length") || line.contains("reason=stop"),
                "request should complete normally: {line}"
            );
            assert!(!line.contains("ttft_us=-"), "completed request has a TTFT: {line}");
        }
        // The replica's weight load is part of the canonical trace.
        assert!(a.lines().any(|l| l.starts_with("load job=")), "no load line in trace");
    }

    #[test]
    fn cold_start_charges_size_proportional_load_before_first_token() {
        // Two models cold-start side by side: intel-neural-7b loads in
        // 30 s, mixtral-8x7b in 120 s (SimProfile.load_secs). The trace
        // must show each group's weight load taking exactly its modeled
        // time, and no first token before the load finished.
        let stack = SimStack::start(SimStackConfig {
            seed: 5,
            services: vec![
                ServiceSpec::sim("intel-neural-7b", 1.0),
                ServiceSpec::sim("mixtral-8x7b", 1.0),
            ],
            // Queue budget must cover the longest cold start (120 s).
            queue_timeout: Duration::from_secs(300),
            ..Default::default()
        });
        stack.submit_chat_at(
            1_000_000,
            SimRequest { user: "u-small".into(), max_tokens: 4, ..Default::default() },
        );
        stack.submit_chat_at(
            1_000_000,
            SimRequest {
                user: "u-big".into(),
                model: "mixtral-8x7b".into(),
                max_tokens: 4,
                ..Default::default()
            },
        );
        assert!(stack.run_until_settled(Duration::from_secs(1200)));
        let trace = stack.trace();
        let load_of = |svc: &str| -> (u64, u64) {
            let line = trace
                .lines()
                .find(|l| l.starts_with("load ") && l.contains(&format!("service={svc} ")))
                .unwrap_or_else(|| panic!("no load line for {svc}: {trace}"));
            let field = |key: &str| -> u64 {
                line.split_whitespace()
                    .find_map(|kv| kv.strip_prefix(key))
                    .and_then(|v| v.parse().ok())
                    .unwrap()
            };
            (field("start_us="), field("ready_us="))
        };
        let (s_small, r_small) = load_of("intel-neural-7b");
        let (s_big, r_big) = load_of("mixtral-8x7b");
        assert_eq!(r_small - s_small, 30_000_000, "7B load is 30 s");
        assert_eq!(r_big - s_big, 120_000_000, "8x7B load is 120 s");
        // No request finished before its model's load did: the queue wait
        // absorbs the cold start (`ttft_us` is engine-side and excludes it).
        for r in stack.records() {
            let ready = if r.model == "intel-neural-7b" { r_small } else { r_big };
            assert_eq!(r.finish_reason, "length", "{r:?}");
            assert!(
                r.finish_us >= ready,
                "request finished at {} before {} was loaded at {ready}",
                r.finish_us,
                r.model,
            );
        }
    }

    #[test]
    fn scale_from_zero_pays_one_load_and_keep_alive_skips_the_second() {
        // A 0-instance group: the first request wakes it and pays the
        // weight load; a second request inside the keep-alive window finds
        // the replica warm and pays none.
        let mut spec = ServiceSpec::sim("intel-neural-7b", 1.0);
        spec.min_instances = 0;
        spec.keep_alive = Duration::from_secs(600);
        let stack = SimStack::start(SimStackConfig {
            seed: 9,
            services: vec![spec],
            queue_timeout: Duration::from_secs(120),
            ..Default::default()
        });
        stack.submit_chat_at(
            10_000_000,
            SimRequest { user: "u-0".into(), max_tokens: 4, ..Default::default() },
        );
        stack.submit_chat_at(
            90_000_000,
            SimRequest { user: "u-1".into(), max_tokens: 4, ..Default::default() },
        );
        assert!(stack.run_until_settled(Duration::from_secs(600)));
        let trace = stack.trace();
        assert_eq!(
            trace.lines().filter(|l| l.starts_with("load ")).count(),
            1,
            "exactly one weight load for two requests: {trace}"
        );
        let recs = stack.records();
        assert_eq!(recs.len(), 2);
        let first = recs.iter().find(|r| r.user == "u-0").unwrap();
        let second = recs.iter().find(|r| r.user == "u-1").unwrap();
        // First request waits out scheduler tick + 30 s load; the second
        // lands on the still-warm replica and turns around in seconds.
        assert!(first.finish_us - first.submit_us > 30_000_000, "{first:?}");
        assert!(second.finish_us - second.submit_us < 5_000_000, "{second:?}");
    }

    #[test]
    fn rate_limit_and_queue_timeout_paths_produce_records() {
        let stack = SimStack::start(SimStackConfig {
            seed: 3,
            rate_limit_rps: Some(1.0),
            queue_timeout: Duration::from_secs(5),
            ..Default::default()
        });
        // A burst of 3 from one user at t=1s: bucket capacity 1 ⇒ two are
        // rejected at the gateway. No instance is ready yet (cold start
        // lasts ~35 s), so the surviving request times out in queue.
        for _ in 0..3 {
            stack.submit_chat_at(
                1_000_000,
                SimRequest { user: "burster".into(), ..Default::default() },
            );
        }
        assert!(stack.run_until_settled(Duration::from_secs(60)));
        let mut reasons: Vec<String> =
            stack.records().iter().map(|r| r.finish_reason.clone()).collect();
        reasons.sort();
        assert_eq!(reasons, vec!["queue_timeout", "rate_limited", "rate_limited"]);
    }

    #[test]
    fn fault_plan_replays_identically_and_folds_into_trace() {
        // Gray every node (the single replica lands on one of them), then
        // flap the link for ~1 s mid-stream.
        let run = |with_faults: bool| {
            let mut plan = FaultPlan::new();
            if with_faults {
                for i in 1..=10 {
                    plan = plan.at(
                        39_000_000,
                        FaultEvent::GraySlow {
                            node: format!("ggpu{i:02}"),
                            factor_milli: 3000,
                        },
                    );
                }
                plan = plan
                    .at(40_050_000, FaultEvent::LinkDown)
                    .at(41_000_000, FaultEvent::LinkUp);
            }
            let stack =
                SimStack::start(SimStackConfig { seed: 11, faults: plan, ..Default::default() });
            for i in 0..5u64 {
                stack.submit_chat_at(
                    40_000_000 + i * 10_000,
                    SimRequest {
                        user: format!("user-{i}"),
                        prompt: format!("hello from user {i}"),
                        max_tokens: 8,
                        ..Default::default()
                    },
                );
            }
            assert!(stack.run_until_settled(Duration::from_secs(600)));
            stack.trace()
        };
        let a = run(true);
        let b = run(true);
        assert_eq!(a, b, "same seed + same fault plan => byte-identical traces");
        assert_eq!(a.matches("fault at_us=").count(), 12, "all applied faults fold in");
        assert!(a.contains("fault at_us=40050000 link_down"));
        assert!(a.contains("fault at_us=41000000 link_up"));
        assert!(a.contains("gray_slow node=ggpu01 factor_milli=3000"));
        for line in a.lines().filter(|l| l.starts_with("req=")) {
            assert!(
                line.contains("reason=length") || line.contains("reason=stop"),
                "faults degrade but do not kill these requests: {line}"
            );
        }
        // The plan must change behaviour, not just annotate: request lines
        // (slower decode, frozen stream) differ from the fault-free run.
        let baseline = run(false);
        assert!(!baseline.contains("fault at_us="), "empty plan stays invisible");
        let req_lines = |t: &str| {
            t.lines().filter(|l| l.starts_with("req=")).map(String::from).collect::<Vec<_>>()
        };
        assert_ne!(req_lines(&a), req_lines(&baseline));
    }

    #[test]
    fn shed_watermark_refuses_excess_load_deterministically() {
        let stack = SimStack::start(SimStackConfig {
            seed: 11,
            shed_watermark: 2,
            ..Default::default()
        });
        for i in 0..6u64 {
            stack.submit_chat_at(
                40_000_000,
                SimRequest { user: format!("user-{i}"), max_tokens: 8, ..Default::default() },
            );
        }
        assert!(stack.run_until_settled(Duration::from_secs(600)));
        let shed = stack
            .records()
            .iter()
            .filter(|r| r.finish_reason == "shed_overload")
            .count();
        assert_eq!(shed, 4, "watermark 2 admits two of a six-deep instant burst");
        assert_eq!(stack.metrics().counter("sim_shed_total", &[]).get(), 4);
        assert!(stack
            .records()
            .iter()
            .filter(|r| r.finish_reason != "shed_overload")
            .all(|r| r.placed_job.is_some()));
    }

    #[test]
    fn brownout_clamps_token_budgets_past_the_watermark() {
        let stack = SimStack::start(SimStackConfig {
            seed: 11,
            brownout_watermark: 1,
            brownout_max_tokens: 4,
            ..Default::default()
        });
        for i in 0..3u64 {
            stack.submit_chat_at(
                40_000_000,
                SimRequest { user: format!("user-{i}"), max_tokens: 64, ..Default::default() },
            );
        }
        assert!(stack.run_until_settled(Duration::from_secs(600)));
        assert_eq!(stack.metrics().counter("sim_brownout_total", &[]).get(), 2);
        let mut recs = stack.records();
        recs.sort_by_key(|r| r.id);
        // Requests 2 and 3 arrived above the watermark: clamped budgets.
        assert!(recs[1].completion_tokens <= 4, "{recs:?}");
        assert!(recs[2].completion_tokens <= 4, "{recs:?}");
    }
}
