//! Full-stack assembly — Figure 1 in one process group.
//!
//! Wires every component exactly along the paper's request path:
//!
//! ```text
//! client ── gateway (auth, routes, rate limits)          [ESX server]
//!              │
//!              ├── webapp (browser-only state)
//!              ├── external proxy (GPT-4 wrapper)
//!              └── HPC proxy ══ SSH(ForceCommand) ══╗   (pool of N
//!                                                   ║    connections)
//!                                                   ║    [HPC platform]
//!                     cloud interface script ◄──────╝
//!                        │ routing table
//!                        ├── scheduler script ── Slurm sim ── GPU nodes
//!                        └── vLLM-like servers (SimBackend / PJRT tiny)
//! ```
//!
//! Examples, integration tests and every bench build on this.

pub mod builder;
pub mod sim;

pub use builder::StackBuilder;
pub use sim::{SimRecord, SimRequest, SimStack, SimStackConfig};

use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::analytics::RequestLog;
use crate::auth::SsoProvider;
use crate::external::ExternalLlmService;
use crate::gateway::{Consumer, Gateway, ModelRegistry, ModelStatus, Route};
use crate::hpcproxy::{HpcProxy, ProxyConfig};
use crate::interface::CloudInterface;
use crate::scheduler::{RealLauncher, SchedulerConfig, ServiceScheduler, ServiceSpec};
use crate::slurm::{ClusterSpec, SlurmSim};
use crate::sshsim::{AuthorizedKey, AuthorizedKeys, KeyPair, SshServer, SshServerConfig};
use crate::util::clock::WallClock;
use crate::util::http::{self, Server};
use crate::util::json::Json;
use crate::util::metrics::Registry;
use crate::webapp::WebApp;

/// The ForceCommand every deployment pins the proxy key to.
pub const CLOUD_INTERFACE_CMD: &str = "/opt/saia/cloud_interface";

/// Stack-wide configuration.
pub struct StackConfig {
    pub cluster: ClusterSpec,
    pub services: Vec<ServiceSpec>,
    /// Wall-time scale for simulated model load times (1.0 = minutes-long
    /// 70B cold starts; tests use ~1e-3).
    pub load_time_scale: f64,
    /// Keepalive/tick interval (paper: 5 s; tests use tens of ms).
    pub keepalive: Duration,
    /// How long the cloud interface queues a request waiting for a
    /// routable instance (e.g. through a scale-from-zero cold start)
    /// before failing it with `queue_timeout`.
    pub queue_timeout: Duration,
    /// Also stand up the external GPT-4 wrapper route.
    pub with_external: bool,
    /// Emulated ESX↔HPC wire time per SSH frame (Table 1/2 benches set
    /// this; everything else leaves it at zero).
    pub ssh_link_frame_delay: Duration,
    /// Persistent SSH connections in the HPC proxy pool (1 = the paper's
    /// single-connection baseline; more breaks the ~200 RPS SSH ceiling).
    pub ssh_pool_size: usize,
    /// Per-connection channel cap used for pool placement (MaxSessions).
    pub ssh_max_channels: usize,
    /// Dual-channel streaming (off = the paper's single-channel baseline):
    /// control traffic stays on the pooled lanes while `infer` reply bytes
    /// ride dedicated bulk connections. Client-visible output is
    /// byte-identical in both modes.
    pub dual_channel: bool,
    /// Bulk token-delivery connections the proxy keeps per upstream when
    /// `dual_channel` is on.
    pub ssh_bulk_lanes: usize,
    /// Zero-copy SSE serving in every instance engine: token frames are
    /// spliced into a pre-dumped JSON template instead of re-serializing a
    /// `Json` tree per chunk (byte-identical output either way).
    pub zero_copy_sse: bool,
    /// Emulated serialized wire time per *server→client* SSH frame — the
    /// reply-direction mirror of `ssh_link_frame_delay`, used by the
    /// stream-saturation bench; everything else leaves it at zero.
    pub ssh_server_frame_delay: Duration,
    /// Engine-side disconnect handling: `true` frees a batch slot the
    /// moment its client vanishes; `false` is the run-to-completion
    /// baseline the abandonment bench measures against.
    pub abort_on_disconnect: bool,
    /// Max prompt tokens an engine prefills per iteration per sequence
    /// (chunked prefill); 0 = unchunked.
    pub prefill_chunk: usize,
    /// Content-hash KV prefix reuse in every instance engine; `false` is
    /// the prefill-everything baseline the multi-turn bench measures
    /// against.
    pub prefix_cache: bool,
    /// Scheduler tuning (renew margin, scavenger tier, drain grace).
    pub scheduler: SchedulerConfig,
}

impl Default for StackConfig {
    fn default() -> StackConfig {
        StackConfig {
            cluster: ClusterSpec::kisski(),
            services: vec![ServiceSpec::sim("intel-neural-7b", 0.0)],
            load_time_scale: 0.001,
            keepalive: Duration::from_millis(50),
            queue_timeout: Duration::from_secs(30),
            with_external: true,
            ssh_link_frame_delay: Duration::ZERO,
            ssh_pool_size: 1,
            ssh_max_channels: 8,
            dual_channel: false,
            ssh_bulk_lanes: 2,
            zero_copy_sse: false,
            ssh_server_frame_delay: Duration::ZERO,
            abort_on_disconnect: true,
            prefill_chunk: crate::llmserver::EngineConfig::default().prefill_chunk,
            prefix_cache: true,
            scheduler: SchedulerConfig::default(),
        }
    }
}

/// Everything running.
pub struct ChatAiStack {
    pub metrics: Registry,
    pub log: RequestLog,
    pub sso: SsoProvider,
    pub slurm: Arc<Mutex<SlurmSim>>,
    pub scheduler: Arc<ServiceScheduler>,
    pub ssh_server: SshServer,
    pub proxy: Arc<HpcProxy>,
    pub proxy_http: Server,
    pub gateway_server: Server,
    /// Model-addressable API: name → route resolution + `GET /v1/models`.
    pub registry: Arc<ModelRegistry>,
    pub webapp: WebApp,
    pub external: Option<ExternalLlmService>,
    /// Research-group API key provisioned by default.
    pub api_key: String,
    /// §7.1.4: the platform key clients seal E2EE payloads with.
    pub e2ee_key: KeyPair,
}

impl ChatAiStack {
    /// Start from a raw [`StackConfig`]. Prefer [`super::StackBuilder`]
    /// for new code — it shares one deployment description with
    /// [`SimStack`] so a bench and its paired test cannot drift apart;
    /// this remains the underlying entry point (and the escape hatch for
    /// real-stack-only knobs).
    pub fn start(cfg: StackConfig) -> Result<ChatAiStack> {
        let metrics = Registry::new();
        let log = RequestLog::new();

        // --- HPC platform ------------------------------------------------
        let slurm = Arc::new(Mutex::new(SlurmSim::new(cfg.cluster.clone())));
        let clock = WallClock::new();
        let launcher = Arc::new(
            RealLauncher::new(metrics.clone(), cfg.load_time_scale).with_engine_config(
                crate::llmserver::EngineConfig {
                    abort_on_disconnect: cfg.abort_on_disconnect,
                    prefill_chunk: cfg.prefill_chunk,
                    prefix_cache: cfg.prefix_cache,
                    zero_copy_sse: cfg.zero_copy_sse,
                    ..Default::default()
                },
            ),
        );
        let scheduler = Arc::new(ServiceScheduler::new(
            slurm.clone(),
            clock,
            launcher,
            cfg.services.clone(),
            cfg.scheduler.clone(),
            metrics.clone(),
        ));
        // §7.1.4 E2EE platform key + §7.1.3 cold-start queueing are on by
        // default: sealed bodies decrypt only here, and infer calls wait
        // out a scale-from-zero cold start.
        let e2ee_key = KeyPair::generate(0x2EE);
        let interface = Arc::new(
            CloudInterface::new(scheduler.clone(), metrics.clone())
                .with_platform_key(e2ee_key.clone())
                .with_queue_timeout(cfg.queue_timeout),
        );

        // --- the circuit breaker -----------------------------------------
        let key = KeyPair::generate(0xE5C);
        let mut authorized = AuthorizedKeys::new();
        authorized.add(AuthorizedKey {
            fingerprint: key.fingerprint(),
            force_command: Some(CLOUD_INTERFACE_CMD.into()),
            options: vec!["no-pty".into(), "no-port-forwarding".into(), "restrict".into()],
            comment: "esx-hpc-proxy (functional account)".into(),
        });
        let ssh_server = SshServer::start_with(
            authorized,
            vec![key.clone()],
            vec![(CLOUD_INTERFACE_CMD.into(), interface)],
            SshServerConfig {
                frame_delay: cfg.ssh_server_frame_delay,
                ..SshServerConfig::default()
            },
        )?;

        // --- ESX side -----------------------------------------------------
        let proxy = HpcProxy::connect(
            &ssh_server.addr.to_string(),
            key,
            ProxyConfig {
                keepalive: cfg.keepalive,
                reconnect_backoff: Duration::from_millis(50),
                link_frame_delay: cfg.ssh_link_frame_delay,
                pool_size: cfg.ssh_pool_size,
                max_channels_per_conn: cfg.ssh_max_channels,
                dual_channel: cfg.dual_channel,
                bulk_lanes: cfg.ssh_bulk_lanes,
            },
            metrics.clone(),
        )?;
        let proxy_http = proxy.clone().into_http()?;

        let sso = SsoProvider::new();
        sso.register("demo@uni-goettingen.de", "demo-password");

        let model_names: Vec<String> = cfg.services.iter().map(|s| s.name.clone()).collect();
        let webapp = WebApp::start(model_names.clone())?;

        let external = if cfg.with_external {
            Some(ExternalLlmService::start("gpt-4", Duration::from_millis(5))?)
        } else {
            None
        };

        let mut routes = Vec::new();
        for name in &model_names {
            // The proxy advertises capacity = connections × channels; with
            // several proxy upstreams the gateway balances by that weight.
            // One retry: a request that dies because its instance was
            // preempted or walltime-killed re-enters the interface, which
            // picks a healthy instance — duplicating at worst some
            // inference compute, never a side effect.
            routes.push(
                Route::new(
                    name,
                    &format!("/v1/m/{name}/"),
                    vec![proxy_http.url()],
                    &format!("/infer/{name}"),
                )
                .with_weights(vec![proxy.capacity()])
                .with_retries(1),
            );
        }
        if let Some(ext) = &external {
            // §5.8: strict rate limit + group restriction on the paid
            // route — and NO retries: a transport error after the paid
            // provider accepted the POST must not double-bill a
            // generation.
            routes.push(
                Route::new("gpt-4", "/v1/m/gpt-4/", vec![ext.url()], "/v1/chat/completions")
                    .with_rate_limit(50.0)
                    .with_groups(&["research", "web"]),
            );
        }
        routes.push(Route::new("webapp", "/chat", vec![webapp.url()], "/").public());

        let api_key = "key-research-0001".to_string();
        let consumers = vec![
            Consumer { id: "api-research".into(), api_key: api_key.clone(), group: "research".into() },
            Consumer {
                id: "api-student".into(),
                api_key: "key-student-0001".into(),
                group: "students".into(),
            },
        ];
        let gateway = Gateway::new(routes, consumers, Some(sso.clone()), metrics.clone(), log.clone());

        // Model-addressable API: every configured replica group registers
        // under its own name, with live status pulled straight from the
        // scheduler's routing table — `/v1/chat/completions` resolves the
        // body `model` here, and `GET /v1/models` lists the fleet.
        let registry = ModelRegistry::new();
        for spec in &cfg.services {
            let sched = scheduler.clone();
            let name = spec.name.clone();
            let scale_from_zero = spec.min_instances == 0;
            registry.register(&spec.name, &spec.name, move || ModelStatus {
                ready: sched.routing.ready_instances(&name).len(),
                total: sched.routing.instances(&name).len(),
                scale_from_zero,
            });
        }
        if external.is_some() {
            // The external wrapper is always addressable; capacity is the
            // provider's concern, not this fleet's.
            registry.register("gpt-4", "gpt-4", || ModelStatus {
                ready: 1,
                total: 1,
                scale_from_zero: false,
            });
        }
        gateway.set_model_registry(registry.clone());
        let gateway_server = gateway.start()?;

        Ok(ChatAiStack {
            metrics,
            log,
            sso,
            slurm,
            scheduler,
            ssh_server,
            proxy,
            proxy_http,
            gateway_server,
            registry,
            webapp,
            external,
            api_key,
            e2ee_key,
        })
    }

    pub fn gateway_url(&self) -> String {
        self.gateway_server.url()
    }

    /// Wait until a service has ≥1 ready instance (scheduler ticks run on
    /// the proxy keepalive; this just polls the routing table).
    pub fn wait_ready(&self, service: &str, timeout: Duration) -> Result<()> {
        let start = std::time::Instant::now();
        while start.elapsed() < timeout {
            if !self.scheduler.routing.ready_instances(service).is_empty() {
                return Ok(());
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        Err(anyhow!("service {service} not ready within {timeout:?}"))
    }

    /// One chat completion through the entire stack, via the unified
    /// model-addressable endpoint (the body `model` picks the route).
    pub fn chat(&self, model: &str, message: &str) -> Result<(u16, Json)> {
        let body = Json::obj()
            .set("model", model)
            .set(
                "messages",
                vec![Json::obj().set("role", "user").set("content", message)],
            )
            .set("stream", false);
        let resp = http::request(
            "POST",
            &format!("{}/v1/chat/completions", self.gateway_url()),
            &[
                ("authorization", &format!("Bearer {}", self.api_key)),
                ("content-type", "application/json"),
            ],
            body.dump().as_bytes(),
        )?;
        let json = resp.json_body().unwrap_or(Json::Null);
        Ok((resp.status, json))
    }

    /// Streaming chat; returns the concatenated token text.
    pub fn chat_stream(&self, model: &str, message: &str) -> Result<String> {
        let body = Json::obj()
            .set("model", model)
            .set(
                "messages",
                vec![Json::obj().set("role", "user").set("content", message)],
            )
            .set("stream", true);
        let mut parser = http::SseParser::default();
        let mut text = String::new();
        http::request_stream(
            "POST",
            &format!("{}/v1/chat/completions", self.gateway_url()),
            &[
                ("authorization", &format!("Bearer {}", self.api_key)),
                ("content-type", "application/json"),
            ],
            body.dump().as_bytes(),
            |chunk| {
                for event in parser.push(chunk) {
                    if event == "[DONE]" {
                        continue;
                    }
                    if let Ok(j) = Json::parse(&event) {
                        if let Some(c) = j.at(&["choices", "0", "delta", "content"]) {
                            if let Some(s) = c.as_str() {
                                text.push_str(s);
                            }
                        }
                    }
                }
            },
        )?;
        Ok(text)
    }

    /// §7.1.4: end-to-end-encrypted chat — the body is sealed for the HPC
    /// platform; the gateway, proxy and SSH layers forward ciphertext only.
    /// Sealed bodies are opaque to the gateway, so the model rides the URL
    /// (the per-model path route), not the encrypted body.
    pub fn chat_sealed(&self, model: &str, message: &str) -> Result<(u16, Json)> {
        let body = Json::obj()
            .set("model", model)
            .set(
                "messages",
                vec![Json::obj().set("role", "user").set("content", message)],
            )
            .set("stream", false);
        // Nonce from wall time; uniqueness is what matters.
        let mut nonce = [0u8; 16];
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap_or_default();
        nonce[..16].copy_from_slice(&t.as_nanos().to_le_bytes()[..16]);
        let sealed =
            crate::interface::e2ee::seal_request(&self.e2ee_key, nonce, body.dump().as_bytes());
        let resp = http::request(
            "POST",
            &format!("{}/v1/m/{model}/", self.gateway_url()),
            &[
                ("authorization", &format!("Bearer {}", self.api_key)),
                ("content-type", "application/octet-stream"),
            ],
            &sealed,
        )?;
        if resp.status != 200 {
            return Ok((resp.status, resp.json_body().unwrap_or(Json::Null)));
        }
        let plain = crate::interface::e2ee::open_response(&self.e2ee_key, &resp.body)
            .map_err(|e| anyhow!("unseal: {e}"))?;
        let json = Json::parse(std::str::from_utf8(&plain)?).map_err(|e| anyhow!("{e}"))?;
        Ok((resp.status, json))
    }

    pub fn stop(&mut self) {
        self.proxy.stop();
        self.ssh_server.stop();
    }
}

impl Drop for ChatAiStack {
    fn drop(&mut self) {
        self.stop();
    }
}
