//! Session cryptography for the SSH-shaped channel.
//!
//! Real primitives (AES-128-CTR + HMAC-SHA256, encrypt-then-MAC, per-frame
//! replay counters); simulated identity (possession of the 32-byte key
//! secret stands in for a private key, its SHA-256 hex digest for the
//! public fingerprint). See module docs in `sshsim` for why that is an
//! acceptable substitution for the circuit-breaker evaluation.

use aes::cipher::{BlockEncrypt, KeyInit};
use aes::Aes128;
use hmac::{Hmac, Mac};
use sha2::{Digest, Sha256};

type HmacSha256 = Hmac<Sha256>;

/// An SSH-sim key pair: 32-byte secret, fingerprint = SHA-256(secret).
#[derive(Clone)]
pub struct KeyPair {
    secret: [u8; 32],
}

impl KeyPair {
    /// Deterministic key generation from a seed (reproducible tests/sims).
    pub fn generate(seed: u64) -> KeyPair {
        let mut h = Sha256::new();
        h.update(b"chat-hpc-ssh-sim-key");
        h.update(seed.to_le_bytes());
        let digest = h.finalize();
        let mut secret = [0u8; 32];
        secret.copy_from_slice(&digest);
        KeyPair { secret }
    }

    pub fn from_secret(secret: [u8; 32]) -> KeyPair {
        KeyPair { secret }
    }

    /// Hex SHA-256 fingerprint (the "public key" in authorized_keys).
    pub fn fingerprint(&self) -> String {
        hex(&Sha256::digest(self.secret))
    }

    /// Prove possession: HMAC over both nonces (the handshake "signature").
    pub fn prove(&self, client_nonce: &[u8; 16], server_nonce: &[u8; 16]) -> [u8; 32] {
        let mut mac = <HmacSha256 as Mac>::new_from_slice(&self.secret).unwrap();
        mac.update(b"chat-hpc-handshake");
        mac.update(client_nonce);
        mac.update(server_nonce);
        let out = mac.finalize().into_bytes();
        let mut proof = [0u8; 32];
        proof.copy_from_slice(&out);
        proof
    }

    /// Derive directional session keys from the secret + nonces.
    pub fn derive_session(
        &self,
        client_nonce: &[u8; 16],
        server_nonce: &[u8; 16],
        is_client: bool,
    ) -> SessionCrypto {
        let derive = |label: &[u8]| -> [u8; 32] {
            let mut mac = <HmacSha256 as Mac>::new_from_slice(&self.secret).unwrap();
            mac.update(label);
            mac.update(client_nonce);
            mac.update(server_nonce);
            let out = mac.finalize().into_bytes();
            let mut k = [0u8; 32];
            k.copy_from_slice(&out);
            k
        };
        let c2s_enc = derive(b"c2s-enc");
        let c2s_mac = derive(b"c2s-mac");
        let s2c_enc = derive(b"s2c-enc");
        let s2c_mac = derive(b"s2c-mac");
        let (send_enc, send_mac, recv_enc, recv_mac) = if is_client {
            (c2s_enc, c2s_mac, s2c_enc, s2c_mac)
        } else {
            (s2c_enc, s2c_mac, c2s_enc, c2s_mac)
        };
        SessionCrypto {
            send_cipher: <Aes128 as KeyInit>::new_from_slice(&send_enc[..16]).unwrap(),
            send_mac_key: send_mac,
            recv_cipher: <Aes128 as KeyInit>::new_from_slice(&recv_enc[..16]).unwrap(),
            recv_mac_key: recv_mac,
            send_ctr: 0,
            recv_ctr: 0,
        }
    }
}

/// Directional frame encryption state.
pub struct SessionCrypto {
    send_cipher: Aes128,
    send_mac_key: [u8; 32],
    recv_cipher: Aes128,
    recv_mac_key: [u8; 32],
    send_ctr: u64,
    recv_ctr: u64,
}

/// CTR keystream: E(k, frame_ctr || block_ctr) xored over the payload.
fn ctr_xor(cipher: &Aes128, frame_ctr: u64, data: &mut [u8]) {
    let mut block = [0u8; 16];
    for (i, chunk) in data.chunks_mut(16).enumerate() {
        block[..8].copy_from_slice(&frame_ctr.to_le_bytes());
        block[8..16].copy_from_slice(&(i as u64).to_le_bytes());
        let mut ks = aes::Block::from(block);
        cipher.encrypt_block(&mut ks);
        for (b, k) in chunk.iter_mut().zip(ks.iter()) {
            *b ^= k;
        }
    }
}

fn frame_mac(key: &[u8; 32], frame_ctr: u64, ciphertext: &[u8]) -> [u8; 32] {
    let mut mac = <HmacSha256 as Mac>::new_from_slice(key).unwrap();
    mac.update(&frame_ctr.to_le_bytes());
    mac.update(ciphertext);
    let out = mac.finalize().into_bytes();
    let mut tag = [0u8; 32];
    tag.copy_from_slice(&out);
    tag
}

impl SessionCrypto {
    /// Encrypt-then-MAC one frame: returns `ciphertext || tag(32)`.
    pub fn seal(&mut self, plaintext: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(plaintext.len() + 32);
        self.seal_into(plaintext, &mut out);
        out
    }

    /// [`seal`](Self::seal) appending into a caller-supplied buffer (the
    /// pooled hot path: no allocation per frame).
    pub fn seal_into(&mut self, plaintext: &[u8], out: &mut Vec<u8>) {
        let ctr = self.send_ctr;
        self.send_ctr += 1;
        let start = out.len();
        out.extend_from_slice(plaintext);
        ctr_xor(&self.send_cipher, ctr, &mut out[start..]);
        let tag = frame_mac(&self.send_mac_key, ctr, &out[start..]);
        out.extend_from_slice(&tag);
    }

    /// Verify + decrypt one frame. Enforces the monotonic counter (replay
    /// and reorder protection).
    pub fn open(&mut self, sealed: &[u8]) -> Result<Vec<u8>, String> {
        let mut out = Vec::with_capacity(sealed.len().saturating_sub(32));
        self.open_into(sealed, &mut out)?;
        Ok(out)
    }

    /// [`open`](Self::open) appending the plaintext into a caller-supplied
    /// buffer (the pooled hot path: no allocation per frame).
    pub fn open_into(&mut self, sealed: &[u8], out: &mut Vec<u8>) -> Result<(), String> {
        if sealed.len() < 32 {
            return Err("frame too short".into());
        }
        let (ciphertext, tag) = sealed.split_at(sealed.len() - 32);
        let ctr = self.recv_ctr;
        let want = frame_mac(&self.recv_mac_key, ctr, ciphertext);
        // Constant-time compare.
        let mut diff = 0u8;
        for (a, b) in want.iter().zip(tag.iter()) {
            diff |= a ^ b;
        }
        if diff != 0 {
            return Err("MAC verification failed (tamper or replay)".into());
        }
        self.recv_ctr += 1;
        let start = out.len();
        out.extend_from_slice(ciphertext);
        ctr_xor(&self.recv_cipher, ctr, &mut out[start..]);
        Ok(())
    }
}

pub fn hex(data: &[u8]) -> String {
    data.iter().map(|b| format!("{b:02x}")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pair() -> (SessionCrypto, SessionCrypto) {
        let kp = KeyPair::generate(7);
        let cn = [1u8; 16];
        let sn = [2u8; 16];
        (kp.derive_session(&cn, &sn, true), kp.derive_session(&cn, &sn, false))
    }

    #[test]
    fn seal_open_roundtrip() {
        let (mut c, mut s) = pair();
        for msg in [&b"hello"[..], &[0u8; 100], &b""[..], &[0xffu8; 33]] {
            let sealed = c.seal(msg);
            assert_eq!(s.open(&sealed).unwrap(), msg);
        }
        // And the reverse direction with independent keys.
        let sealed = s.seal(b"reply");
        assert_eq!(c.open(&sealed).unwrap(), b"reply");
    }

    #[test]
    fn seal_into_open_into_append_without_clobbering() {
        let (mut c, mut s) = pair();
        let mut sealed = b"prefix".to_vec();
        c.seal_into(b"payload", &mut sealed);
        assert_eq!(&sealed[..6], b"prefix");
        let mut plain = b"head".to_vec();
        s.open_into(&sealed[6..], &mut plain).unwrap();
        assert_eq!(plain, b"headpayload");
    }

    #[test]
    fn ciphertext_differs_from_plaintext_and_between_frames() {
        let (mut c, _s) = pair();
        let a = c.seal(b"same message");
        let b = c.seal(b"same message");
        assert_ne!(&a[..12], b"same message");
        assert_ne!(a, b, "frame counter must randomize the keystream");
    }

    #[test]
    fn tamper_detected() {
        let (mut c, mut s) = pair();
        let mut sealed = c.seal(b"payload");
        sealed[0] ^= 1;
        assert!(s.open(&sealed).is_err());
    }

    #[test]
    fn replay_rejected() {
        let (mut c, mut s) = pair();
        let sealed = c.seal(b"one");
        assert!(s.open(&sealed).is_ok());
        // Replaying the same frame fails: the receive counter moved on.
        assert!(s.open(&sealed).is_err());
    }

    #[test]
    fn reorder_rejected() {
        let (mut c, mut s) = pair();
        let f1 = c.seal(b"first");
        let f2 = c.seal(b"second");
        assert!(s.open(&f2).is_err(), "out-of-order frame must fail");
        let _ = f1;
    }

    #[test]
    fn wrong_key_cannot_open() {
        let kp2 = KeyPair::generate(99);
        let (mut c, _) = pair();
        let mut other = kp2.derive_session(&[1u8; 16], &[2u8; 16], false);
        assert!(other.open(&c.seal(b"secret")).is_err());
    }

    #[test]
    fn fingerprint_stable_and_distinct() {
        assert_eq!(KeyPair::generate(1).fingerprint(), KeyPair::generate(1).fingerprint());
        assert_ne!(KeyPair::generate(1).fingerprint(), KeyPair::generate(2).fingerprint());
        assert_eq!(KeyPair::generate(1).fingerprint().len(), 64);
    }

    #[test]
    fn proof_binds_both_nonces() {
        let kp = KeyPair::generate(5);
        let p1 = kp.prove(&[1; 16], &[2; 16]);
        let p2 = kp.prove(&[1; 16], &[3; 16]);
        let p3 = kp.prove(&[4; 16], &[2; 16]);
        assert_ne!(p1, p2);
        assert_ne!(p1, p3);
    }
}
