//! SSH-sim wire protocol: handshake, multiplexed channels, keepalives.
//!
//! One TCP connection carries many concurrent `exec` channels (the paper's
//! HPC Proxy multiplexes every inference request plus a 5-second keepalive
//! over a single persistent SSH connection — Table 2's ~200 RPS SSH ceiling
//! is this serialization). Frames are sealed by [`SessionCrypto`].
//!
//! Frame plaintext layout: `type(1) | channel(4, LE) | payload`.
//!
//! The ForceCommand enforcement point is in [`SshServer`]: after
//! authentication the requested command is *replaced* by the
//! `authorized_keys` `command=` value; the request only survives as the
//! `SSH_ORIGINAL_COMMAND` argument to the handler — byte-for-byte OpenSSH
//! semantics, and the paper's circuit breaker.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::crypto::{KeyPair, SessionCrypto};
use super::AuthorizedKeys;
use crate::util::clock::{Clock, WallClock};
use crate::util::faults::{FrameFault, LinkFaults};
use crate::util::http::{frame_buf_acquire, frame_buf_release, write_all_vectored, Frame};

const FRAME_EXEC: u8 = 0;
const FRAME_DATA: u8 = 1;
const FRAME_EOF: u8 = 2;
const FRAME_EXIT: u8 = 3;
const FRAME_PING: u8 = 4;
const FRAME_PONG: u8 = 5;
/// Client-initiated channel abandonment (OpenSSH `SSH_MSG_CHANNEL_CLOSE`):
/// the server stops the handler's output and releases the channel's
/// `MaxSessions` slot as soon as the handler returns.
const FRAME_CLOSE: u8 = 6;
// --- dual-channel streaming (control/bulk split) ---
/// Sent once, right after the handshake, on a connection that will carry
/// token payloads only; payload = the lane's `bulk_id` (u64 LE). The server
/// registers the connection so control-lane execs can route output to it.
const FRAME_BULK_HELLO: u8 = 7;
/// Server→client token payload on a bulk connection; `chan` = subchannel.
const FRAME_BULK_DATA: u8 = 8;
/// Server→client end-of-payload marker for one bulk subchannel. The exit
/// code still rides the control lane (FRAME_EXIT).
const FRAME_BULK_EOF: u8 = 9;
/// Client→server abandonment of one bulk subchannel (the bulk-side mirror
/// of FRAME_CLOSE).
const FRAME_BULK_CLOSE: u8 = 10;
/// Single-frame exec on a control connection with output redirected to a
/// bulk lane. Payload: `bulk_id(8 LE) | subchan(4 LE) | cmd_len(4 LE) |
/// cmd | stdin` — command and stdin inline, so channel setup costs ONE
/// control frame instead of the classic EXEC+DATA+EOF triple.
const FRAME_EXEC_BULK: u8 = 11;

const MAX_FRAME: usize = 16 * 1024 * 1024;

/// Exit code reported when the server refuses to open another channel on a
/// connection that is already at `max_sessions` (OpenSSH surfaces the same
/// condition as "channel open failed").
pub const EXIT_CHANNEL_REJECTED: i32 = 254;

/// Pseudo exit code returned by `exec_stream_ctl` when the *consumer*
/// abandoned the channel (CHANNEL_CLOSE sent); the real remote exit code
/// never arrives because the channel is already gone.
pub const EXIT_CANCELLED: i32 = 253;

/// What a command execution produces.
#[derive(Debug, Clone)]
pub struct ExecReply {
    pub exit_code: i32,
    pub stdout: Vec<u8>,
}

/// Streaming chunk delivered to `exec_stream` consumers. Data rides a
/// reference-counted [`Frame`] so the decrypted payload travels from the
/// reader thread to the consumer without a copy.
#[derive(Debug)]
pub enum StreamChunk {
    Data(Frame),
    Exit(i32),
}

/// Server-side command implementation.
///
/// `command` is the command line actually being run (the ForceCommand when
/// one is pinned); `original_command` is what the client requested —
/// `SSH_ORIGINAL_COMMAND` in OpenSSH terms. `stdin` is the full request
/// body; `out` streams stdout chunks back. Returns the exit code.
pub trait CommandHandler: Send + Sync {
    fn exec(
        &self,
        command: &str,
        original_command: &str,
        stdin: &[u8],
        out: &mut dyn FnMut(&[u8]) -> Result<()>,
    ) -> i32;
}

impl<F> CommandHandler for F
where
    F: Fn(&str, &str, &[u8], &mut dyn FnMut(&[u8]) -> Result<()>) -> i32 + Send + Sync,
{
    fn exec(
        &self,
        command: &str,
        original_command: &str,
        stdin: &[u8],
        out: &mut dyn FnMut(&[u8]) -> Result<()>,
    ) -> i32 {
        self(command, original_command, stdin, out)
    }
}

// ---------------------------------------------------------------------------
// Framing helpers
// ---------------------------------------------------------------------------

fn write_frame(
    mut w: &mut (impl Write + ?Sized),
    crypto: &mut SessionCrypto,
    ty: u8,
    chan: u32,
    payload: &[u8],
) -> Result<()> {
    // Pooled scratch buffers + one vectored write for `len || sealed`:
    // zero steady-state allocations and one syscall per frame.
    let mut plain = frame_buf_acquire();
    plain.push(ty);
    plain.extend_from_slice(&chan.to_le_bytes());
    plain.extend_from_slice(payload);
    let mut sealed = frame_buf_acquire();
    crypto.seal_into(&plain, &mut sealed);
    frame_buf_release(plain);
    let len = (sealed.len() as u32).to_le_bytes();
    let res = write_all_vectored(&mut w, &[&len, &sealed])
        .and_then(|_| w.flush().map_err(Into::into));
    frame_buf_release(sealed);
    res
}

fn read_frame(r: &mut impl Read, crypto: &mut SessionCrypto) -> Result<(u8, u32, Frame)> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        bail!("oversized frame {len}");
    }
    let mut sealed = frame_buf_acquire();
    sealed.resize(len, 0);
    if let Err(e) = r.read_exact(&mut sealed) {
        frame_buf_release(sealed);
        return Err(e.into());
    }
    let mut plain = frame_buf_acquire();
    if let Err(e) = crypto.open_into(&sealed, &mut plain) {
        frame_buf_release(sealed);
        frame_buf_release(plain);
        return Err(anyhow!(e));
    }
    frame_buf_release(sealed);
    if plain.len() < 5 {
        frame_buf_release(plain);
        bail!("short frame");
    }
    let ty = plain[0];
    let chan = u32::from_le_bytes([plain[1], plain[2], plain[3], plain[4]]);
    // The payload is exposed as an offset view over the decrypted buffer:
    // the 5 header bytes ride along unseen, nothing is re-copied, and the
    // buffer returns to the pool when the last Frame clone drops.
    Ok((ty, chan, Frame::from_vec_offset(plain, 5)))
}

/// Seal one frame into its on-wire form (`len(4 LE) || sealed`). Public for
/// the framing property test and the per-frame microbench.
pub fn encode_frame(crypto: &mut SessionCrypto, ty: u8, chan: u32, payload: &[u8]) -> Vec<u8> {
    let mut plain = Vec::with_capacity(payload.len() + 5);
    plain.push(ty);
    plain.extend_from_slice(&chan.to_le_bytes());
    plain.extend_from_slice(payload);
    let sealed = crypto.seal(&plain);
    let mut wire = Vec::with_capacity(sealed.len() + 4);
    wire.extend_from_slice(&(sealed.len() as u32).to_le_bytes());
    wire.extend_from_slice(&sealed);
    wire
}

/// Decode one frame from a reader — the exact inverse of [`encode_frame`]
/// (and the code path every live connection runs).
pub fn decode_frame(r: &mut impl Read, crypto: &mut SessionCrypto) -> Result<(u8, u32, Frame)> {
    read_frame(r, crypto)
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Per-server metrics exposed to the monitoring layer.
#[derive(Default)]
pub struct SshServerStats {
    pub sessions_accepted: AtomicU64,
    pub sessions_rejected: AtomicU64,
    pub execs: AtomicU64,
    pub pings: AtomicU64,
    pub forced_commands: AtomicU64,
    /// Channel opens refused because a connection hit `max_sessions`.
    pub channel_rejections: AtomicU64,
    /// Client-initiated CHANNEL_CLOSE frames received (cancelled channels).
    pub channels_cancelled: AtomicU64,
    /// Bulk (token-delivery) connections registered via BULK_HELLO.
    pub bulk_conns: AtomicU64,
    /// Execs whose output was routed to a bulk lane (FRAME_EXEC_BULK).
    pub bulk_execs: AtomicU64,
}

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct SshServerConfig {
    /// Maximum concurrent exec channels per connection, like OpenSSH
    /// `MaxSessions`. `0` = unlimited (the seed behaviour).
    pub max_sessions: usize,
    /// Emulated serialized wire time charged per server→client frame, held
    /// under the writer lock of whichever connection carries the frame —
    /// the reply-direction mirror of `SshClient`'s `frame_delay`, so the
    /// stream-saturation bench can reproduce a congested SSH leg in both
    /// directions. Always the wall clock (`SimStack` never sets it).
    /// Zero (off) by default.
    pub frame_delay: Duration,
    /// Seeded wire-fault source consulted once per server→client frame:
    /// latency spikes, corruption (the peer's MAC check fails), truncation
    /// (mid-frame lane death). `None` (default) is the exact pre-fault
    /// write path.
    pub faults: Option<Arc<LinkFaults>>,
}

impl Default for SshServerConfig {
    fn default() -> SshServerConfig {
        SshServerConfig { max_sessions: 0, frame_delay: Duration::ZERO, faults: None }
    }
}

/// The sshd of the HPC service node.
pub struct SshServer {
    pub addr: std::net::SocketAddr,
    pub stats: Arc<SshServerStats>,
    stop: Arc<AtomicBool>,
    sessions: Arc<Mutex<Vec<TcpStream>>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// A registered bulk (token-delivery) connection, shared between the
/// control sessions that route exec output to it.
#[derive(Clone)]
struct BulkConn {
    writer: Arc<Mutex<(TcpStream, SessionCrypto)>>,
    /// subchannel -> cancel flag of the handler streaming to it.
    cancels: Arc<Mutex<BTreeMap<u32, Arc<AtomicBool>>>>,
}

struct ServerShared {
    authorized: AuthorizedKeys,
    /// Host-side key material (the functional account's keys).
    keys: BTreeMap<String, KeyPair>,
    /// command path (first token) -> handler.
    handlers: BTreeMap<String, Arc<dyn CommandHandler>>,
    stats: Arc<SshServerStats>,
    cfg: SshServerConfig,
    /// bulk_id -> registered bulk connection (dual-channel mode). Lives on
    /// the server (not the session) because EXEC_BULK arrives on a control
    /// connection but streams to a different, bulk connection.
    bulks: Mutex<BTreeMap<u64, BulkConn>>,
}

/// Per-connection server→client wire model: the emulated serialized frame
/// delay plus the optional fault source. Cloned into each handler thread.
#[derive(Clone)]
struct Wire {
    delay: Duration,
    faults: Option<Arc<LinkFaults>>,
}

/// One serialized server→client frame: the emulated wire-time charge and
/// the write both happen under the connection's writer lock (one wire per
/// connection; bulk lanes are extra wires). When a fault source is armed,
/// each frame may instead be delayed, delivered corrupted (the peer's MAC
/// check kills the lane), or truncated mid-frame with the wire dropped.
fn server_send(
    writer: &Mutex<(TcpStream, SessionCrypto)>,
    wire: &Wire,
    ty: u8,
    chan: u32,
    payload: &[u8],
) -> Result<()> {
    let mut g = writer.lock().unwrap();
    if !wire.delay.is_zero() {
        std::thread::sleep(wire.delay);
    }
    let (ref mut sock, ref mut crypto) = *g;
    if let Some(faults) = &wire.faults {
        match faults.next_frame_fault() {
            FrameFault::Pass => {}
            FrameFault::Delay(spike) => {
                if !spike.is_zero() {
                    std::thread::sleep(spike);
                }
            }
            FrameFault::Corrupt => {
                // Seal normally, then flip bits in the sealed body: the
                // frame arrives, fails the peer's integrity check, and the
                // lane dies exactly as if the wire corrupted it.
                let mut on_wire = encode_frame(crypto, ty, chan, payload);
                *on_wire.last_mut().expect("sealed frame is never empty") ^= 0xFF;
                sock.write_all(&on_wire)?;
                sock.flush()?;
                return Ok(());
            }
            FrameFault::Truncate => {
                // Deliver a prefix of the sealed frame, then drop the wire:
                // the peer observes a mid-frame connection death.
                let on_wire = encode_frame(crypto, ty, chan, payload);
                let cut = 4 + (on_wire.len() - 4) / 2;
                let _ = sock.write_all(&on_wire[..cut]);
                let _ = sock.flush();
                let _ = sock.shutdown(std::net::Shutdown::Both);
                bail!("fault injection truncated frame on channel {chan}");
            }
        }
    }
    write_frame(sock, crypto, ty, chan, payload)
}

impl SshServer {
    /// Start an sshd on an ephemeral port with default config (no
    /// per-connection session cap).
    ///
    /// `keys` must contain the key material for every fingerprint in
    /// `authorized`; `handlers` maps command paths (the first whitespace
    /// token of the resolved command line) to implementations.
    pub fn start(
        authorized: AuthorizedKeys,
        keys: Vec<KeyPair>,
        handlers: Vec<(String, Arc<dyn CommandHandler>)>,
    ) -> Result<SshServer> {
        SshServer::start_with(authorized, keys, handlers, SshServerConfig::default())
    }

    /// Start an sshd with explicit config (e.g. a `MaxSessions` cap).
    pub fn start_with(
        authorized: AuthorizedKeys,
        keys: Vec<KeyPair>,
        handlers: Vec<(String, Arc<dyn CommandHandler>)>,
        cfg: SshServerConfig,
    ) -> Result<SshServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stats = Arc::new(SshServerStats::default());
        let shared = Arc::new(ServerShared {
            authorized,
            keys: keys.into_iter().map(|k| (k.fingerprint(), k)).collect(),
            handlers: handlers.into_iter().collect(),
            stats: stats.clone(),
            cfg,
            bulks: Mutex::new(BTreeMap::new()),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let sessions: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let sessions2 = sessions.clone();
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if let Ok(clone) = stream.try_clone() {
                            sessions2.lock().unwrap().push(clone);
                        }
                        let sh = shared.clone();
                        std::thread::spawn(move || {
                            let _ = serve_session(stream, sh);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(SshServer { addr, stats, stop, sessions, handle: Some(handle) })
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Kill live sessions so clients observe the outage immediately.
        for s in self.sessions.lock().unwrap().drain(..) {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    /// Hard-close one accepted connection (index in accept order) without
    /// stopping the server — simulates a single pool member's link dying
    /// while the others stay up.
    pub fn kill_session(&self, index: usize) -> bool {
        let sessions = self.sessions.lock().unwrap();
        match sessions.get(index) {
            Some(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
                true
            }
            None => false,
        }
    }

    /// Number of TCP connections accepted so far (dead ones included).
    pub fn session_count(&self) -> usize {
        self.sessions.lock().unwrap().len()
    }
}

impl Drop for SshServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_session(mut stream: TcpStream, shared: Arc<ServerShared>) -> Result<()> {
    stream.set_nodelay(true)?;
    // --- handshake ---
    let mut fp_buf = [0u8; 64];
    stream.read_exact(&mut fp_buf)?;
    let fingerprint = std::str::from_utf8(&fp_buf)?.to_string();
    let mut client_nonce = [0u8; 16];
    stream.read_exact(&mut client_nonce)?;

    let (Some(entry), Some(key)) =
        (shared.authorized.lookup(&fingerprint), shared.keys.get(&fingerprint))
    else {
        shared.stats.sessions_rejected.fetch_add(1, Ordering::Relaxed);
        let _ = stream.write_all(&[0u8]); // reject
        return Ok(());
    };
    let entry = entry.clone();

    // Server nonce from OS entropy-ish source (time + addr hash is enough
    // for the simulation; uniqueness is what matters for CTR keys).
    let mut server_nonce = [0u8; 16];
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap_or_default();
    server_nonce[..8].copy_from_slice(&t.as_nanos().to_le_bytes()[..8]);
    server_nonce[8..].copy_from_slice(&(&stream as *const _ as u64).to_le_bytes());
    stream.write_all(&[1u8])?; // accept
    stream.write_all(&server_nonce)?;

    let mut proof = [0u8; 32];
    stream.read_exact(&mut proof)?;
    if proof != key.prove(&client_nonce, &server_nonce) {
        shared.stats.sessions_rejected.fetch_add(1, Ordering::Relaxed);
        return Ok(());
    }
    shared.stats.sessions_accepted.fetch_add(1, Ordering::Relaxed);

    let mut recv_crypto = key.derive_session(&client_nonce, &server_nonce, false);
    // Writer shares the socket: split send/recv crypto states.
    let send_crypto = key.derive_session(&client_nonce, &server_nonce, false);
    let writer = Arc::new(Mutex::new((stream.try_clone()?, send_crypto)));

    // Server→client wire model: emulated frame time + optional fault
    // source (see `SshServerConfig`).
    let wire = Wire { delay: shared.cfg.frame_delay, faults: shared.cfg.faults.clone() };
    // Set when this connection declared itself a bulk lane (BULK_HELLO).
    let mut my_bulk_id: Option<u64> = None;
    // Per-channel stdin accumulators.
    let mut stdin_bufs: BTreeMap<u32, Vec<u8>> = BTreeMap::new();
    // Concurrent exec channels on THIS connection (MaxSessions accounting):
    // counted from channel open (EXEC) until the handler thread finishes.
    let inflight = Arc::new(AtomicUsize::new(0));
    // Channels whose client sent CHANNEL_CLOSE while a handler was running:
    // the flag makes the handler's next output write fail, which is how the
    // cancellation reaches CommandHandler implementations.
    let cancels: Arc<Mutex<BTreeMap<u32, Arc<AtomicBool>>>> =
        Arc::new(Mutex::new(BTreeMap::new()));

    loop {
        let (ty, chan, payload) = match read_frame(&mut stream, &mut recv_crypto) {
            Ok(f) => f,
            Err(_) => break, // disconnect
        };
        match ty {
            FRAME_PING => {
                shared.stats.pings.fetch_add(1, Ordering::Relaxed);
                let _ = server_send(&writer, &wire, FRAME_PONG, chan, &payload);
            }
            FRAME_EXEC => {
                // *** MaxSessions: refuse the channel open outright. ***
                let cap = shared.cfg.max_sessions;
                if cap > 0 && inflight.load(Ordering::SeqCst) >= cap {
                    shared.stats.channel_rejections.fetch_add(1, Ordering::Relaxed);
                    let _ = server_send(
                        &writer,
                        &wire,
                        FRAME_DATA,
                        chan,
                        format!("sshsim: channel open failed: MaxSessions {cap} reached\n")
                            .as_bytes(),
                    );
                    let _ = server_send(
                        &writer,
                        &wire,
                        FRAME_EXIT,
                        chan,
                        &(EXIT_CHANNEL_REJECTED as u32).to_le_bytes(),
                    );
                    continue;
                }
                inflight.fetch_add(1, Ordering::SeqCst);
                stdin_bufs.insert(chan, payload.to_vec());
            }
            FRAME_DATA => {
                if let Some(buf) = stdin_bufs.get_mut(&chan) {
                    // EXEC payload holds the command; stdin appends after a
                    // NUL separator written by the client.
                    buf.extend_from_slice(&payload);
                }
            }
            FRAME_EOF => {
                // Request complete: resolve + dispatch.
                let Some(buf) = stdin_bufs.remove(&chan) else { continue };
                let inflight = inflight.clone();
                let sep = buf.iter().position(|&b| b == 0).unwrap_or(buf.len());
                let requested = String::from_utf8_lossy(&buf[..sep]).into_owned();
                let stdin = if sep < buf.len() { buf[sep + 1..].to_vec() } else { Vec::new() };

                // *** The ForceCommand circuit breaker. ***
                let (command, original) = match &entry.force_command {
                    Some(forced) => {
                        shared.stats.forced_commands.fetch_add(1, Ordering::Relaxed);
                        (forced.clone(), requested)
                    }
                    None => (requested.clone(), requested),
                };
                shared.stats.execs.fetch_add(1, Ordering::Relaxed);

                let path = command.split_whitespace().next().unwrap_or("").to_string();
                let handler = shared.handlers.get(&path).cloned();
                let w = writer.clone();
                let cancelled = Arc::new(AtomicBool::new(false));
                cancels.lock().unwrap().insert(chan, cancelled.clone());
                let cancels_map = cancels.clone();
                let wire = wire.clone();
                std::thread::spawn(move || {
                    let send =
                        |ty: u8, payload: &[u8]| -> Result<()> {
                            if cancelled.load(Ordering::SeqCst) {
                                bail!("channel {chan} closed by client");
                            }
                            server_send(&w, &wire, ty, chan, payload)
                        };
                    let code = match handler {
                        Some(h) => {
                            let mut out =
                                |chunk: &[u8]| -> Result<()> { send(FRAME_DATA, chunk) };
                            h.exec(&command, &original, &stdin, &mut out)
                        }
                        None => {
                            let _ = send(
                                FRAME_DATA,
                                format!("sshsim: {path}: command not found\n").as_bytes(),
                            );
                            127
                        }
                    };
                    // On a cancelled channel the EXIT frame is suppressed
                    // (the client already forgot the channel); the send
                    // closure's flag check does that for us.
                    let _ = send(FRAME_EXIT, &(code as u32).to_le_bytes());
                    cancels_map.lock().unwrap().remove(&chan);
                    inflight.fetch_sub(1, Ordering::SeqCst);
                });
            }
            FRAME_BULK_HELLO => {
                // This connection becomes a registered token-delivery lane.
                if payload.len() >= 8 {
                    let id = u64::from_le_bytes(payload[..8].try_into().unwrap());
                    let conn = BulkConn {
                        writer: writer.clone(),
                        cancels: Arc::new(Mutex::new(BTreeMap::new())),
                    };
                    shared.bulks.lock().unwrap().insert(id, conn);
                    shared.stats.bulk_conns.fetch_add(1, Ordering::Relaxed);
                    my_bulk_id = Some(id);
                }
            }
            FRAME_BULK_CLOSE => {
                // Client abandoned one bulk subchannel: fail the producing
                // handler's next write (arrives on the bulk connection;
                // `chan` is the subchannel id).
                if let Some(id) = my_bulk_id {
                    let flag = shared
                        .bulks
                        .lock()
                        .unwrap()
                        .get(&id)
                        .and_then(|b| b.cancels.lock().unwrap().get(&chan).cloned());
                    if let Some(flag) = flag {
                        shared.stats.channels_cancelled.fetch_add(1, Ordering::Relaxed);
                        flag.store(true, Ordering::SeqCst);
                    }
                }
            }
            FRAME_EXEC_BULK => {
                // Dual-channel exec: setup, cancel and exit stay on THIS
                // control connection; payload bytes stream to the named
                // bulk lane. Command + stdin arrive inline in this single
                // frame (no DATA/EOF phase).
                if payload.len() < 16 {
                    continue;
                }
                let bulk_id = u64::from_le_bytes(payload[..8].try_into().unwrap());
                let sub = u32::from_le_bytes(payload[8..12].try_into().unwrap());
                let cmd_len =
                    u32::from_le_bytes(payload[12..16].try_into().unwrap()) as usize;
                if payload.len() < 16 + cmd_len {
                    continue;
                }
                let bulk = shared.bulks.lock().unwrap().get(&bulk_id).cloned();
                let Some(bulk) = bulk else {
                    let _ = server_send(
                        &writer,
                        &wire,
                        FRAME_DATA,
                        chan,
                        format!("sshsim: unknown bulk lane {bulk_id}\n").as_bytes(),
                    );
                    let _ = server_send(
                        &writer,
                        &wire,
                        FRAME_EXIT,
                        chan,
                        &(EXIT_CHANNEL_REJECTED as u32).to_le_bytes(),
                    );
                    continue;
                };
                let cap = shared.cfg.max_sessions;
                if cap > 0 && inflight.load(Ordering::SeqCst) >= cap {
                    shared.stats.channel_rejections.fetch_add(1, Ordering::Relaxed);
                    // Resolve the client's bulk wait, then reject on control
                    // exactly like a classic channel-open failure.
                    let _ = server_send(&bulk.writer, &wire, FRAME_BULK_EOF, sub, &[]);
                    let _ = server_send(
                        &writer,
                        &wire,
                        FRAME_DATA,
                        chan,
                        format!("sshsim: channel open failed: MaxSessions {cap} reached\n")
                            .as_bytes(),
                    );
                    let _ = server_send(
                        &writer,
                        &wire,
                        FRAME_EXIT,
                        chan,
                        &(EXIT_CHANNEL_REJECTED as u32).to_le_bytes(),
                    );
                    continue;
                }
                inflight.fetch_add(1, Ordering::SeqCst);
                let requested =
                    String::from_utf8_lossy(&payload[16..16 + cmd_len]).into_owned();
                let stdin = payload[16 + cmd_len..].to_vec();

                // *** The ForceCommand circuit breaker (same as FRAME_EOF). ***
                let (command, original) = match &entry.force_command {
                    Some(forced) => {
                        shared.stats.forced_commands.fetch_add(1, Ordering::Relaxed);
                        (forced.clone(), requested)
                    }
                    None => (requested.clone(), requested),
                };
                shared.stats.execs.fetch_add(1, Ordering::Relaxed);
                shared.stats.bulk_execs.fetch_add(1, Ordering::Relaxed);

                let path = command.split_whitespace().next().unwrap_or("").to_string();
                let handler = shared.handlers.get(&path).cloned();
                let w = writer.clone();
                let inflight = inflight.clone();
                let cancelled = Arc::new(AtomicBool::new(false));
                // One flag, reachable from BOTH lanes: FRAME_CLOSE on the
                // control channel and FRAME_BULK_CLOSE on the subchannel.
                cancels.lock().unwrap().insert(chan, cancelled.clone());
                bulk.cancels.lock().unwrap().insert(sub, cancelled.clone());
                let cancels_map = cancels.clone();
                let wire = wire.clone();
                std::thread::spawn(move || {
                    let bulk_send = |ty: u8, payload: &[u8]| -> Result<()> {
                        if cancelled.load(Ordering::SeqCst) {
                            bail!("bulk subchannel {sub} closed by client");
                        }
                        server_send(&bulk.writer, &wire, ty, sub, payload)
                    };
                    let code = match handler {
                        Some(h) => {
                            let mut out = |chunk: &[u8]| -> Result<()> {
                                bulk_send(FRAME_BULK_DATA, chunk)
                            };
                            h.exec(&command, &original, &stdin, &mut out)
                        }
                        None => {
                            let _ = bulk_send(
                                FRAME_BULK_DATA,
                                format!("sshsim: {path}: command not found\n").as_bytes(),
                            );
                            127
                        }
                    };
                    // Payload end on the bulk lane, exit code on control;
                    // both suppressed after a cancel by the flag check.
                    let _ = bulk_send(FRAME_BULK_EOF, &[]);
                    if !cancelled.load(Ordering::SeqCst) {
                        let _ = server_send(
                            &w,
                            &wire,
                            FRAME_EXIT,
                            chan,
                            &(code as u32).to_le_bytes(),
                        );
                    }
                    bulk.cancels.lock().unwrap().remove(&sub);
                    cancels_map.lock().unwrap().remove(&chan);
                    inflight.fetch_sub(1, Ordering::SeqCst);
                });
            }
            FRAME_CLOSE => {
                shared.stats.channels_cancelled.fetch_add(1, Ordering::Relaxed);
                if stdin_bufs.remove(&chan).is_some() {
                    // Closed before EOF ever dispatched a handler: release
                    // the MaxSessions slot taken at EXEC.
                    inflight.fetch_sub(1, Ordering::SeqCst);
                } else if let Some(flag) = cancels.lock().unwrap().get(&chan) {
                    // Handler running: fail its next output write.
                    flag.store(true, Ordering::SeqCst);
                }
            }
            _ => {}
        }
    }
    // Bulk lane died: deregister it and cancel every handler still
    // streaming to it, so lane slots and MaxSessions accounting free up
    // exactly like a control-lane disconnect (PR 2/PR 4 guarantees).
    if let Some(id) = my_bulk_id {
        if let Some(conn) = shared.bulks.lock().unwrap().remove(&id) {
            for flag in conn.cancels.lock().unwrap().values() {
                flag.store(true, Ordering::SeqCst);
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Connect + authenticate (shared by control and bulk connections).
/// Returns the stream and the directional send/recv crypto states.
fn client_handshake(
    addr: &str,
    key: &KeyPair,
) -> Result<(TcpStream, SessionCrypto, SessionCrypto)> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.set_nodelay(true)?;
    stream.write_all(key.fingerprint().as_bytes())?;
    let mut client_nonce = [0u8; 16];
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap_or_default();
    client_nonce[..8].copy_from_slice(&t.as_nanos().to_le_bytes()[..8]);
    client_nonce[8..].copy_from_slice(&std::process::id().to_le_bytes().repeat(4)[..8]);
    stream.write_all(&client_nonce)?;

    let mut accept = [0u8; 1];
    stream.read_exact(&mut accept)?;
    if accept[0] != 1 {
        bail!("server rejected key {}", key.fingerprint());
    }
    let mut server_nonce = [0u8; 16];
    stream.read_exact(&mut server_nonce)?;
    stream.write_all(&key.prove(&client_nonce, &server_nonce))?;

    let send_crypto = key.derive_session(&client_nonce, &server_nonce, true);
    let recv_crypto = key.derive_session(&client_nonce, &server_nonce, true);
    Ok((stream, send_crypto, recv_crypto))
}

/// Client side of the persistent SSH connection (held by the HPC Proxy).
pub struct SshClient {
    writer: Arc<Mutex<(TcpStream, SessionCrypto)>>,
    channels: Arc<Mutex<BTreeMap<u32, Sender<StreamChunk>>>>,
    pong: Arc<Mutex<BTreeMap<u32, Sender<()>>>>,
    next_chan: AtomicU32,
    dead: Arc<AtomicBool>,
    /// Emulated serialized wire time per frame. Loopback TCP is far faster
    /// than the paper's ESX↔HPC link + OpenSSH channel costs; benches set
    /// this (calibrated against Table 1's measured SSH leg) to reproduce
    /// the single-connection ~200 RPS ceiling of Table 2. Zero by default.
    frame_delay: Duration,
    /// Where `frame_delay` is charged: the wall clock by default; a
    /// `SimClock` makes wire time advance virtual microseconds instead.
    clock: Arc<dyn Clock>,
}

impl SshClient {
    /// Connect and authenticate with `key`.
    pub fn connect(addr: &str, key: &KeyPair) -> Result<SshClient> {
        SshClient::connect_with(addr, key, Duration::ZERO)
    }

    /// Connect with an emulated per-frame wire delay (see `frame_delay`).
    pub fn connect_with(addr: &str, key: &KeyPair, frame_delay: Duration) -> Result<SshClient> {
        SshClient::connect_with_clock(addr, key, frame_delay, WallClock::new())
    }

    /// Like [`SshClient::connect_with`], but wire-time charges go to the
    /// injected clock (virtual microseconds under a `SimClock`).
    pub fn connect_with_clock(
        addr: &str,
        key: &KeyPair,
        frame_delay: Duration,
        clock: Arc<dyn Clock>,
    ) -> Result<SshClient> {
        let (stream, send_crypto, mut recv_crypto) = client_handshake(addr, key)?;
        let writer = Arc::new(Mutex::new((stream.try_clone()?, send_crypto)));
        let channels: Arc<Mutex<BTreeMap<u32, Sender<StreamChunk>>>> =
            Arc::new(Mutex::new(BTreeMap::new()));
        let pong: Arc<Mutex<BTreeMap<u32, Sender<()>>>> = Arc::new(Mutex::new(BTreeMap::new()));
        let dead = Arc::new(AtomicBool::new(false));

        // Reader thread: route frames to channel receivers.
        let channels2 = channels.clone();
        let pong2 = pong.clone();
        let dead2 = dead.clone();
        std::thread::spawn(move || {
            let mut stream = stream;
            loop {
                match read_frame(&mut stream, &mut recv_crypto) {
                    Ok((ty, chan, payload)) => match ty {
                        FRAME_DATA => {
                            if let Some(tx) = channels2.lock().unwrap().get(&chan) {
                                let _ = tx.send(StreamChunk::Data(payload));
                            }
                        }
                        FRAME_EXIT => {
                            let code = i32::from_le_bytes([
                                payload[0], payload[1], payload[2], payload[3],
                            ]);
                            if let Some(tx) = channels2.lock().unwrap().remove(&chan) {
                                let _ = tx.send(StreamChunk::Exit(code));
                            }
                        }
                        FRAME_PONG => {
                            if let Some(tx) = pong2.lock().unwrap().remove(&chan) {
                                let _ = tx.send(());
                            }
                        }
                        _ => {}
                    },
                    Err(_) => {
                        dead2.store(true, Ordering::SeqCst);
                        // Wake all waiters by dropping their senders.
                        channels2.lock().unwrap().clear();
                        pong2.lock().unwrap().clear();
                        break;
                    }
                }
            }
        });

        Ok(SshClient { writer, channels, pong, next_chan: AtomicU32::new(1), dead, frame_delay, clock })
    }

    pub fn is_alive(&self) -> bool {
        !self.dead.load(Ordering::SeqCst)
    }

    fn send(&self, ty: u8, chan: u32, payload: &[u8]) -> Result<()> {
        if !self.is_alive() {
            bail!("ssh connection is down");
        }
        let mut g = self.writer.lock().unwrap();
        if !self.frame_delay.is_zero() {
            // Serialized wire time: held under the writer lock on purpose —
            // one connection, one wire (the paper's SSH bottleneck).
            self.clock.sleep(self.frame_delay);
        }
        let (ref mut sock, ref mut crypto) = *g;
        write_frame(sock, crypto, ty, chan, payload).map_err(|e| {
            self.dead.store(true, Ordering::SeqCst);
            e
        })
    }

    /// Write several frames of one channel under a single writer-lock
    /// acquisition: a pipelined exec leaves EXEC+DATA+EOF back-to-back on
    /// the wire instead of letting other channels interleave (and pay the
    /// lock) between each frame.
    fn send_pipelined(&self, chan: u32, frames: &[(u8, &[u8])]) -> Result<()> {
        if !self.is_alive() {
            bail!("ssh connection is down");
        }
        let mut g = self.writer.lock().unwrap();
        if !self.frame_delay.is_zero() {
            // Serialized wire time, one slot per frame (see `send`).
            self.clock.sleep(self.frame_delay * frames.len() as u32);
        }
        let (ref mut sock, ref mut crypto) = *g;
        for (ty, payload) in frames {
            write_frame(sock, crypto, *ty, chan, payload).map_err(|e| {
                self.dead.store(true, Ordering::SeqCst);
                e
            })?;
        }
        Ok(())
    }

    fn open_channel(&self) -> (u32, Receiver<StreamChunk>) {
        let chan = self.next_chan.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = channel();
        self.channels.lock().unwrap().insert(chan, tx);
        (chan, rx)
    }

    /// Exec channels currently open (in-flight requests) — the load signal
    /// the proxy pool uses for least-loaded placement.
    pub fn active_channels(&self) -> usize {
        self.channels.lock().unwrap().len()
    }

    /// Execute `command` with `stdin`, streaming stdout chunks to
    /// `on_chunk`; returns the exit code.
    pub fn exec_stream(
        &self,
        command: &str,
        stdin: &[u8],
        mut on_chunk: impl FnMut(&[u8]),
    ) -> Result<i32> {
        self.exec_stream_ctl(command, stdin, |chunk| {
            on_chunk(chunk);
            true
        })
    }

    /// Cancellable exec: like [`exec_stream`], but `on_chunk` returns
    /// whether to keep consuming. Returning `false` sends CHANNEL_CLOSE,
    /// drops the channel from this connection's accounting immediately
    /// (the lane is placeable again before the server even reacts), and
    /// returns [`EXIT_CANCELLED`].
    pub fn exec_stream_ctl(
        &self,
        command: &str,
        stdin: &[u8],
        mut on_chunk: impl FnMut(&[u8]) -> bool,
    ) -> Result<i32> {
        let (chan, rx) = self.open_channel();
        // EXEC payload = command; stdin travels as DATA after a NUL marker.
        let mut body = vec![0u8];
        body.extend_from_slice(stdin);
        let frames: [(u8, &[u8]); 3] =
            [(FRAME_EXEC, command.as_bytes()), (FRAME_DATA, &body), (FRAME_EOF, &[])];
        if let Err(e) = self.send_pipelined(chan, &frames) {
            self.channels.lock().unwrap().remove(&chan);
            return Err(e);
        }
        loop {
            match rx.recv_timeout(Duration::from_secs(60)) {
                Ok(StreamChunk::Data(d)) => {
                    if !on_chunk(&d) {
                        self.channels.lock().unwrap().remove(&chan);
                        // Best-effort: a dead connection already freed the
                        // server side, so the close frame may not go out.
                        let _ = self.send(FRAME_CLOSE, chan, &[]);
                        return Ok(EXIT_CANCELLED);
                    }
                }
                Ok(StreamChunk::Exit(code)) => return Ok(code),
                Err(_) => {
                    self.channels.lock().unwrap().remove(&chan);
                    // Same ghost-generation hazard as an explicit abandon:
                    // without a close the server handler keeps its
                    // MaxSessions slot and keeps generating for nobody.
                    let _ = self.send(FRAME_CLOSE, chan, &[]);
                    bail!("ssh exec timed out or connection lost");
                }
            }
        }
    }

    /// Dual-channel exec: setup/cancel/exit ride THIS control connection
    /// (one EXEC_BULK frame carrying command + stdin inline), while every
    /// payload byte streams over `bulk`'s subchannel. Cancellation via
    /// `on_chunk -> false` mirrors [`exec_stream_ctl`](Self::exec_stream_ctl):
    /// both lanes' accounting is freed immediately and the server handler's
    /// next write fails.
    pub fn exec_stream_bulk_ctl(
        &self,
        bulk: &BulkChannel,
        command: &str,
        stdin: &[u8],
        mut on_chunk: impl FnMut(&[u8]) -> bool,
    ) -> Result<i32> {
        let (chan, ctl_rx) = self.open_channel();
        let (sub, bulk_rx) = bulk.open_sub();
        let cmd = command.as_bytes();
        let mut payload = Vec::with_capacity(16 + cmd.len() + stdin.len());
        payload.extend_from_slice(&bulk.id().to_le_bytes());
        payload.extend_from_slice(&sub.to_le_bytes());
        payload.extend_from_slice(&(cmd.len() as u32).to_le_bytes());
        payload.extend_from_slice(cmd);
        payload.extend_from_slice(stdin);
        if let Err(e) = self.send(FRAME_EXEC_BULK, chan, &payload) {
            self.channels.lock().unwrap().remove(&chan);
            bulk.forget_sub(sub);
            return Err(e);
        }
        drop(payload);
        let deadline = Instant::now() + Duration::from_secs(60);
        // Exit observed on the control lane while the bulk side is still
        // open (rejection, early handler exit, cross-connection races).
        let mut ctl_exit: Option<i32> = None;
        loop {
            match bulk_rx.recv_timeout(Duration::from_millis(100)) {
                Ok(StreamChunk::Data(d)) => {
                    if !on_chunk(&d) {
                        // Abandon on both lanes; local accounting freed now.
                        self.channels.lock().unwrap().remove(&chan);
                        let _ = self.send(FRAME_CLOSE, chan, &[]);
                        bulk.close_sub(sub);
                        return Ok(EXIT_CANCELLED);
                    }
                }
                Ok(StreamChunk::Exit(_)) => {
                    // BULK_EOF: payload complete; the real exit code rides
                    // the control lane (possibly already here).
                    if let Some(code) = ctl_exit {
                        return Ok(code);
                    }
                    loop {
                        match ctl_rx.recv_timeout(Duration::from_secs(60)) {
                            Ok(StreamChunk::Exit(code)) => return Ok(code),
                            // Notices (e.g. rejection text) ride control.
                            Ok(StreamChunk::Data(_)) => {}
                            Err(_) => {
                                self.channels.lock().unwrap().remove(&chan);
                                bail!("ssh exec (bulk): control exit never arrived");
                            }
                        }
                    }
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    if ctl_exit.is_none() {
                        match ctl_rx.try_recv() {
                            Ok(StreamChunk::Exit(code)) => ctl_exit = Some(code),
                            Ok(StreamChunk::Data(_)) => {}
                            Err(_) => {}
                        }
                    }
                    if let Some(code) = ctl_exit {
                        // Control finished but no BULK_EOF yet: grace-drain
                        // in-flight bulk frames, then surface the verdict.
                        let drain_until = Instant::now() + Duration::from_millis(50);
                        loop {
                            let left = drain_until.saturating_duration_since(Instant::now());
                            match bulk_rx.recv_timeout(left) {
                                Ok(StreamChunk::Data(d)) => {
                                    let _ = on_chunk(&d);
                                }
                                Ok(StreamChunk::Exit(_)) | Err(_) => break,
                            }
                        }
                        bulk.forget_sub(sub);
                        return Ok(code);
                    }
                    if Instant::now() >= deadline {
                        self.channels.lock().unwrap().remove(&chan);
                        let _ = self.send(FRAME_CLOSE, chan, &[]);
                        bulk.close_sub(sub);
                        bail!("ssh exec (bulk) timed out");
                    }
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    // The bulk connection died mid-stream. Free the control
                    // channel too (best-effort close; a dead control lane
                    // already freed the server side).
                    self.channels.lock().unwrap().remove(&chan);
                    let _ = self.send(FRAME_CLOSE, chan, &[]);
                    bail!("bulk channel lost mid-stream");
                }
            }
        }
    }

    /// Execute and collect stdout.
    pub fn exec(&self, command: &str, stdin: &[u8]) -> Result<ExecReply> {
        let mut stdout = Vec::new();
        let exit_code = self.exec_stream(command, stdin, |chunk| {
            stdout.extend_from_slice(chunk);
        })?;
        Ok(ExecReply { exit_code, stdout })
    }

    /// Keepalive ping; returns the round-trip time.
    pub fn ping(&self) -> Result<Duration> {
        let chan = self.next_chan.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = channel();
        self.pong.lock().unwrap().insert(chan, tx);
        let start = Instant::now();
        self.send(FRAME_PING, chan, &[])?;
        rx.recv_timeout(Duration::from_secs(10))
            .map_err(|_| anyhow!("ping timeout"))?;
        Ok(start.elapsed())
    }
}

// ---------------------------------------------------------------------------
// Bulk channel (dual-channel streaming, token-delivery side)
// ---------------------------------------------------------------------------

/// The token-delivery half of dual-channel streaming: one extra
/// authenticated TCP connection that carries ONLY bulk frames (token
/// payloads + their EOF markers), keeping the pooled control lanes free
/// for exec setup, cancel, keepalive and exit status. Many concurrent
/// requests multiplex subchannels over one bulk lane; the proxy places
/// each request on its least-loaded lane via [`active_subchannels`]
/// (BulkChannel::active_subchannels).
pub struct BulkChannel {
    writer: Arc<Mutex<(TcpStream, SessionCrypto)>>,
    subs: Arc<Mutex<BTreeMap<u32, Sender<StreamChunk>>>>,
    next_sub: AtomicU32,
    dead: Arc<AtomicBool>,
    id: u64,
    /// Emulated serialized wire time for client→server bulk frames (rare:
    /// only HELLO and BULK_CLOSE go this direction).
    frame_delay: Duration,
    clock: Arc<dyn Clock>,
}

impl BulkChannel {
    /// Connect, authenticate, and register as bulk lane `id`. The id must
    /// be unique per live lane (the proxy derives it from a generation
    /// counter so a reconnect never collides with its stale predecessor).
    pub fn connect(addr: &str, key: &KeyPair, id: u64) -> Result<BulkChannel> {
        BulkChannel::connect_with_clock(addr, key, id, Duration::ZERO, WallClock::new())
    }

    /// Like [`BulkChannel::connect`] with an emulated per-frame wire delay
    /// charged to the injected clock.
    pub fn connect_with_clock(
        addr: &str,
        key: &KeyPair,
        id: u64,
        frame_delay: Duration,
        clock: Arc<dyn Clock>,
    ) -> Result<BulkChannel> {
        let (stream, send_crypto, mut recv_crypto) = client_handshake(addr, key)?;
        let writer = Arc::new(Mutex::new((stream.try_clone()?, send_crypto)));
        {
            // Declare this connection a bulk lane before anything rides it.
            let mut g = writer.lock().unwrap();
            let (ref mut sock, ref mut crypto) = *g;
            write_frame(sock, crypto, FRAME_BULK_HELLO, 0, &id.to_le_bytes())?;
        }
        let subs: Arc<Mutex<BTreeMap<u32, Sender<StreamChunk>>>> =
            Arc::new(Mutex::new(BTreeMap::new()));
        let dead = Arc::new(AtomicBool::new(false));

        // Reader thread: route bulk frames to subchannel receivers.
        let subs2 = subs.clone();
        let dead2 = dead.clone();
        std::thread::spawn(move || {
            let mut stream = stream;
            loop {
                match read_frame(&mut stream, &mut recv_crypto) {
                    Ok((ty, sub, payload)) => match ty {
                        FRAME_BULK_DATA => {
                            if let Some(tx) = subs2.lock().unwrap().get(&sub) {
                                let _ = tx.send(StreamChunk::Data(payload));
                            }
                        }
                        FRAME_BULK_EOF => {
                            // Payload complete. Exit(0) is only the EOF
                            // sentinel; the real code rides control.
                            if let Some(tx) = subs2.lock().unwrap().remove(&sub) {
                                let _ = tx.send(StreamChunk::Exit(0));
                            }
                        }
                        _ => {}
                    },
                    Err(_) => {
                        dead2.store(true, Ordering::SeqCst);
                        // Wake all waiters by dropping their senders.
                        subs2.lock().unwrap().clear();
                        break;
                    }
                }
            }
        });

        Ok(BulkChannel {
            writer,
            subs,
            next_sub: AtomicU32::new(1),
            dead,
            id,
            frame_delay,
            clock,
        })
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn is_alive(&self) -> bool {
        !self.dead.load(Ordering::SeqCst)
    }

    /// Subchannels currently streaming — the lane-load signal the proxy
    /// uses for least-loaded bulk placement.
    pub fn active_subchannels(&self) -> usize {
        self.subs.lock().unwrap().len()
    }

    fn open_sub(&self) -> (u32, Receiver<StreamChunk>) {
        let sub = self.next_sub.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = channel();
        self.subs.lock().unwrap().insert(sub, tx);
        (sub, rx)
    }

    /// Drop local accounting for a subchannel without telling the server
    /// (used when the server already finished it on the control lane).
    fn forget_sub(&self, sub: u32) {
        self.subs.lock().unwrap().remove(&sub);
    }

    /// Abandon a subchannel: local accounting freed immediately, and the
    /// server is told to stop the producer (the bulk-side CHANNEL_CLOSE).
    fn close_sub(&self, sub: u32) {
        self.subs.lock().unwrap().remove(&sub);
        if !self.is_alive() {
            return;
        }
        let mut g = self.writer.lock().unwrap();
        if !self.frame_delay.is_zero() {
            self.clock.sleep(self.frame_delay);
        }
        let (ref mut sock, ref mut crypto) = *g;
        if write_frame(sock, crypto, FRAME_BULK_CLOSE, sub, &[]).is_err() {
            self.dead.store(true, Ordering::SeqCst);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sshsim::AuthorizedKey;

    fn echo_handler() -> Arc<dyn CommandHandler> {
        Arc::new(
            |command: &str,
             original: &str,
             stdin: &[u8],
             out: &mut dyn FnMut(&[u8]) -> Result<()>| {
                let _ = out(format!("cmd={command}\n").as_bytes());
                let _ = out(format!("orig={original}\n").as_bytes());
                let _ = out(b"stdin=");
                let _ = out(stdin);
                0
            },
        )
    }

    fn forced_server(kp: &KeyPair) -> SshServer {
        let mut ak = AuthorizedKeys::new();
        ak.add(AuthorizedKey {
            fingerprint: kp.fingerprint(),
            force_command: Some("/opt/saia/cloud_interface".into()),
            options: vec!["restrict".into()],
            comment: "esx".into(),
        });
        SshServer::start(
            ak,
            vec![kp.clone()],
            vec![("/opt/saia/cloud_interface".into(), echo_handler())],
        )
        .unwrap()
    }

    #[test]
    fn exec_roundtrip_with_force_command() {
        let kp = KeyPair::generate(11);
        let server = forced_server(&kp);
        let client = SshClient::connect(&server.addr.to_string(), &kp).unwrap();
        // The client asks for an arbitrary (malicious) command...
        let reply = client.exec("rm -rf / --no-preserve-root", b"PAYLOAD").unwrap();
        let text = String::from_utf8_lossy(&reply.stdout);
        // ...but the pinned command runs, and the request is demoted to
        // SSH_ORIGINAL_COMMAND.
        assert!(text.contains("cmd=/opt/saia/cloud_interface"), "{text}");
        assert!(text.contains("orig=rm -rf / --no-preserve-root"), "{text}");
        assert!(text.contains("stdin=PAYLOAD"), "{text}");
        assert_eq!(reply.exit_code, 0);
        assert_eq!(server.stats.forced_commands.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn unauthorized_key_rejected() {
        let kp = KeyPair::generate(12);
        let server = forced_server(&kp);
        let rogue = KeyPair::generate(666);
        let err = SshClient::connect(&server.addr.to_string(), &rogue);
        assert!(err.is_err());
        assert_eq!(server.stats.sessions_rejected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn no_handler_means_exit_127() {
        let kp = KeyPair::generate(13);
        let mut ak = AuthorizedKeys::new();
        ak.add(AuthorizedKey {
            fingerprint: kp.fingerprint(),
            force_command: None,
            options: vec![],
            comment: String::new(),
        });
        let server = SshServer::start(ak, vec![kp.clone()], vec![]).unwrap();
        let client = SshClient::connect(&server.addr.to_string(), &kp).unwrap();
        let reply = client.exec("/bin/bash -c evil", b"").unwrap();
        assert_eq!(reply.exit_code, 127);
        assert!(String::from_utf8_lossy(&reply.stdout).contains("command not found"));
    }

    #[test]
    fn concurrent_execs_multiplex_one_connection() {
        let kp = KeyPair::generate(14);
        let server = forced_server(&kp);
        let client = Arc::new(SshClient::connect(&server.addr.to_string(), &kp).unwrap());
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let c = client.clone();
                std::thread::spawn(move || {
                    for j in 0..5 {
                        let body = format!("req-{i}-{j}");
                        let reply = c.exec("x", body.as_bytes()).unwrap();
                        assert!(
                            String::from_utf8_lossy(&reply.stdout)
                                .contains(&format!("stdin={body}")),
                        );
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.stats.execs.load(Ordering::Relaxed), 40);
        assert_eq!(server.stats.sessions_accepted.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn ping_keepalive() {
        let kp = KeyPair::generate(15);
        let server = forced_server(&kp);
        let client = SshClient::connect(&server.addr.to_string(), &kp).unwrap();
        for _ in 0..3 {
            let rtt = client.ping().unwrap();
            assert!(rtt < Duration::from_secs(1));
        }
        assert_eq!(server.stats.pings.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn server_death_detected() {
        let kp = KeyPair::generate(16);
        let mut server = forced_server(&kp);
        let client = SshClient::connect(&server.addr.to_string(), &kp).unwrap();
        assert!(client.is_alive());
        server.stop();
        // Next operation fails and marks the connection dead.
        std::thread::sleep(Duration::from_millis(50));
        let _ = client.ping();
        let _ = client.ping();
        assert!(!client.is_alive() || client.ping().is_err());
    }

    fn faulty_server(kp: &KeyPair, faults: Arc<LinkFaults>) -> SshServer {
        let mut ak = AuthorizedKeys::new();
        ak.add(AuthorizedKey {
            fingerprint: kp.fingerprint(),
            force_command: Some("/opt/saia/cloud_interface".into()),
            options: vec!["restrict".into()],
            comment: "esx".into(),
        });
        SshServer::start_with(
            ak,
            vec![kp.clone()],
            vec![("/opt/saia/cloud_interface".into(), echo_handler())],
            SshServerConfig { faults: Some(faults), ..Default::default() },
        )
        .unwrap()
    }

    #[test]
    fn corrupted_frame_kills_the_lane() {
        let kp = KeyPair::generate(31);
        let faults = Arc::new(LinkFaults::new(1).with_corrupt(1.0));
        let server = faulty_server(&kp, faults.clone());
        let client = SshClient::connect(&server.addr.to_string(), &kp).unwrap();
        // The first server→client frame arrives with clobbered bytes: the
        // MAC check fails and the client treats the lane as dead.
        assert!(client.exec("x", b"").is_err(), "corrupted lane must fail the exec");
        assert!(faults.corrupted.load(Ordering::Relaxed) >= 1);
        assert!(!client.is_alive());
    }

    #[test]
    fn truncated_frame_drops_the_lane_mid_frame() {
        let kp = KeyPair::generate(32);
        let faults = Arc::new(LinkFaults::new(2).with_truncate(1.0));
        let server = faulty_server(&kp, faults.clone());
        let client = SshClient::connect(&server.addr.to_string(), &kp).unwrap();
        assert!(client.exec("x", b"").is_err(), "truncated lane must fail the exec");
        assert!(faults.truncated.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn delay_spikes_slow_frames_but_deliver_them() {
        let kp = KeyPair::generate(33);
        let faults = Arc::new(
            LinkFaults::new(3).with_delay_spike(1.0, Duration::from_millis(30)),
        );
        let server = faulty_server(&kp, faults.clone());
        let client = SshClient::connect(&server.addr.to_string(), &kp).unwrap();
        let t = Instant::now();
        let reply = client.exec("x", b"ok").unwrap();
        assert_eq!(reply.exit_code, 0, "spiked lane still completes");
        assert!(
            t.elapsed() >= Duration::from_millis(30),
            "spike not charged: {:?}",
            t.elapsed()
        );
        assert!(faults.delayed.load(Ordering::Relaxed) >= 1);
    }

    fn slow_handler(ms: u64) -> Arc<dyn CommandHandler> {
        Arc::new(
            move |_c: &str,
                  _o: &str,
                  _i: &[u8],
                  out: &mut dyn FnMut(&[u8]) -> Result<()>| {
                std::thread::sleep(Duration::from_millis(ms));
                let _ = out(b"done");
                0
            },
        )
    }

    #[test]
    fn max_sessions_cap_rejects_excess_channels() {
        let kp = KeyPair::generate(18);
        let mut ak = AuthorizedKeys::new();
        ak.add(AuthorizedKey {
            fingerprint: kp.fingerprint(),
            force_command: Some("/slow".into()),
            options: vec![],
            comment: String::new(),
        });
        let server = SshServer::start_with(
            ak,
            vec![kp.clone()],
            vec![("/slow".into(), slow_handler(200))],
            SshServerConfig { max_sessions: 2, ..Default::default() },
        )
        .unwrap();
        let client = Arc::new(SshClient::connect(&server.addr.to_string(), &kp).unwrap());
        let handles: Vec<_> = (0..6)
            .map(|_| {
                let c = client.clone();
                std::thread::spawn(move || c.exec("x", b"").unwrap().exit_code)
            })
            .collect();
        let codes: Vec<i32> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(codes.iter().any(|&c| c == 0), "some execs must run: {codes:?}");
        assert!(
            codes.iter().any(|&c| c == EXIT_CHANNEL_REJECTED),
            "cap 2 with 6 concurrent execs must reject: {codes:?}"
        );
        assert!(server.stats.channel_rejections.load(Ordering::Relaxed) >= 1);
        // The connection itself survives rejections.
        assert_eq!(client.exec("again", b"").unwrap().exit_code, 0);
    }

    #[test]
    fn active_channels_tracks_inflight_execs() {
        let kp = KeyPair::generate(19);
        let mut ak = AuthorizedKeys::new();
        ak.add(AuthorizedKey {
            fingerprint: kp.fingerprint(),
            force_command: Some("/slow".into()),
            options: vec![],
            comment: String::new(),
        });
        let server = SshServer::start(ak, vec![kp.clone()], vec![("/slow".into(), slow_handler(150))])
            .unwrap();
        let client = Arc::new(SshClient::connect(&server.addr.to_string(), &kp).unwrap());
        assert_eq!(client.active_channels(), 0);
        let c = client.clone();
        let h = std::thread::spawn(move || c.exec("x", b"").unwrap());
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(client.active_channels(), 1, "exec in flight");
        h.join().unwrap();
        assert_eq!(client.active_channels(), 0, "drained after exit");
    }

    #[test]
    fn channel_close_stops_server_handler_and_frees_accounting() {
        let kp = KeyPair::generate(20);
        let mut ak = AuthorizedKeys::new();
        ak.add(AuthorizedKey {
            fingerprint: kp.fingerprint(),
            force_command: Some("/drip".into()),
            options: vec![],
            comment: String::new(),
        });
        // A handler that drips chunks until its output write fails.
        let emitted = Arc::new(AtomicUsize::new(0));
        let stopped_early = Arc::new(AtomicBool::new(false));
        let (em, st) = (emitted.clone(), stopped_early.clone());
        let dripper: Arc<dyn CommandHandler> = Arc::new(
            move |_c: &str, _o: &str, _i: &[u8], out: &mut dyn FnMut(&[u8]) -> Result<()>| {
                for _ in 0..50 {
                    std::thread::sleep(Duration::from_millis(10));
                    if out(b"tok;").is_err() {
                        st.store(true, Ordering::SeqCst);
                        return 1;
                    }
                    em.fetch_add(1, Ordering::SeqCst);
                }
                0
            },
        );
        let server =
            SshServer::start(ak, vec![kp.clone()], vec![("/drip".into(), dripper)]).unwrap();
        let client = SshClient::connect(&server.addr.to_string(), &kp).unwrap();

        let mut seen = 0usize;
        let code = client
            .exec_stream_ctl("x", b"", |_| {
                seen += 1;
                seen < 3 // abandon after the third chunk
            })
            .unwrap();
        assert_eq!(code, EXIT_CANCELLED);
        // Channel accounting freed immediately on the client side.
        assert_eq!(client.active_channels(), 0, "lane not released");
        // The CLOSE frame reached the server and the handler stopped.
        let deadline = Instant::now() + Duration::from_secs(3);
        while !stopped_early.load(Ordering::SeqCst) {
            assert!(Instant::now() < deadline, "server handler never noticed the close");
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(server.stats.channels_cancelled.load(Ordering::Relaxed), 1);
        let produced = emitted.load(Ordering::SeqCst);
        assert!(produced < 50, "handler ran to completion despite close: {produced}");
        // The connection itself survives: a fresh exec runs to completion.
        let reply = client.exec("again", b"").unwrap();
        assert_eq!(reply.exit_code, 0);
    }

    #[test]
    fn cancelled_channel_releases_max_sessions_slot() {
        // Cap 1: while a drip exec is in flight the cap is full; after the
        // client closes the channel the next exec must be admitted.
        let kp = KeyPair::generate(21);
        let mut ak = AuthorizedKeys::new();
        ak.add(AuthorizedKey {
            fingerprint: kp.fingerprint(),
            force_command: Some("/slow".into()),
            options: vec![],
            comment: String::new(),
        });
        let server = SshServer::start_with(
            ak,
            vec![kp.clone()],
            vec![("/slow".into(), slow_handler(400))],
            SshServerConfig { max_sessions: 1, ..Default::default() },
        )
        .unwrap();
        let client = Arc::new(SshClient::connect(&server.addr.to_string(), &kp).unwrap());
        // First exec occupies the only session slot, then gets abandoned.
        let c = client.clone();
        let h = std::thread::spawn(move || {
            c.exec_stream_ctl("x", b"", |_| false).unwrap() // close on first chunk
        });
        assert_eq!(h.join().unwrap(), EXIT_CANCELLED);
        // The handler thread finishes within its sleep; once it does, the
        // slot is free and a new exec is admitted rather than rejected.
        let deadline = Instant::now() + Duration::from_secs(3);
        loop {
            let code = client.exec("y", b"").unwrap().exit_code;
            if code == 0 {
                break;
            }
            assert_eq!(code, EXIT_CHANNEL_REJECTED);
            assert!(Instant::now() < deadline, "MaxSessions slot never released");
            std::thread::sleep(Duration::from_millis(25));
        }
    }

    #[test]
    fn bulk_exec_roundtrip_and_accounting() {
        let kp = KeyPair::generate(23);
        let server = forced_server(&kp);
        let addr = server.addr.to_string();
        let ctl = SshClient::connect(&addr, &kp).unwrap();
        let bulk = BulkChannel::connect(&addr, &kp, 77).unwrap();
        assert!(bulk.is_alive());
        let mut chunks: Vec<String> = Vec::new();
        let code = ctl
            .exec_stream_bulk_ctl(&bulk, "rm -rf /", b"PAYLOAD", |c| {
                chunks.push(String::from_utf8_lossy(c).into_owned());
                true
            })
            .unwrap();
        assert_eq!(code, 0);
        let text = chunks.concat();
        // ForceCommand applies to bulk execs exactly like classic ones.
        assert!(text.contains("cmd=/opt/saia/cloud_interface"), "{text}");
        assert!(text.contains("orig=rm -rf /"), "{text}");
        assert!(text.contains("stdin=PAYLOAD"), "{text}");
        // Accounting drains on both lanes.
        assert_eq!(ctl.active_channels(), 0);
        assert_eq!(bulk.active_subchannels(), 0);
        assert_eq!(server.stats.bulk_conns.load(Ordering::Relaxed), 1);
        assert_eq!(server.stats.bulk_execs.load(Ordering::Relaxed), 1);
        // The pair keeps working for subsequent requests.
        let code = ctl.exec_stream_bulk_ctl(&bulk, "again", b"x", |_| true).unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn bulk_cancel_stops_handler_and_frees_both_lanes() {
        let kp = KeyPair::generate(24);
        let mut ak = AuthorizedKeys::new();
        ak.add(AuthorizedKey {
            fingerprint: kp.fingerprint(),
            force_command: Some("/drip".into()),
            options: vec![],
            comment: String::new(),
        });
        let stopped_early = Arc::new(AtomicBool::new(false));
        let st = stopped_early.clone();
        let dripper: Arc<dyn CommandHandler> = Arc::new(
            move |_c: &str, _o: &str, _i: &[u8], out: &mut dyn FnMut(&[u8]) -> Result<()>| {
                for _ in 0..50 {
                    std::thread::sleep(Duration::from_millis(10));
                    if out(b"tok;").is_err() {
                        st.store(true, Ordering::SeqCst);
                        return 1;
                    }
                }
                0
            },
        );
        let server = SshServer::start_with(
            ak,
            vec![kp.clone()],
            vec![("/drip".into(), dripper)],
            SshServerConfig { max_sessions: 1, ..Default::default() },
        )
        .unwrap();
        let addr = server.addr.to_string();
        let ctl = SshClient::connect(&addr, &kp).unwrap();
        let bulk = BulkChannel::connect(&addr, &kp, 5).unwrap();

        let mut seen = 0usize;
        let code = ctl
            .exec_stream_bulk_ctl(&bulk, "x", b"", |_| {
                seen += 1;
                seen < 3 // abandon after the third chunk
            })
            .unwrap();
        assert_eq!(code, EXIT_CANCELLED);
        // Both lanes' accounting freed immediately on the client side.
        assert_eq!(ctl.active_channels(), 0, "control lane not released");
        assert_eq!(bulk.active_subchannels(), 0, "bulk subchannel not released");
        // The close reached the server and the handler stopped.
        let deadline = Instant::now() + Duration::from_secs(3);
        while !stopped_early.load(Ordering::SeqCst) {
            assert!(Instant::now() < deadline, "server handler never noticed the close");
            std::thread::sleep(Duration::from_millis(10));
        }
        // MaxSessions slot (cap 1) released: the next bulk exec is admitted.
        let deadline = Instant::now() + Duration::from_secs(3);
        loop {
            let code = ctl.exec_stream_bulk_ctl(&bulk, "y", b"", |_| true).unwrap();
            if code != EXIT_CHANNEL_REJECTED {
                break;
            }
            assert!(Instant::now() < deadline, "MaxSessions slot never released");
            std::thread::sleep(Duration::from_millis(25));
        }
    }

    #[test]
    fn bulk_exec_rejected_when_cap_full() {
        let kp = KeyPair::generate(25);
        let mut ak = AuthorizedKeys::new();
        ak.add(AuthorizedKey {
            fingerprint: kp.fingerprint(),
            force_command: Some("/slow".into()),
            options: vec![],
            comment: String::new(),
        });
        let server = SshServer::start_with(
            ak,
            vec![kp.clone()],
            vec![("/slow".into(), slow_handler(400))],
            SshServerConfig { max_sessions: 1, ..Default::default() },
        )
        .unwrap();
        let addr = server.addr.to_string();
        let ctl = Arc::new(SshClient::connect(&addr, &kp).unwrap());
        let bulk = Arc::new(BulkChannel::connect(&addr, &kp, 9).unwrap());
        let (c, b) = (ctl.clone(), bulk.clone());
        let h = std::thread::spawn(move || {
            c.exec_stream_bulk_ctl(&b, "x", b"", |_| true).unwrap()
        });
        std::thread::sleep(Duration::from_millis(100)); // let it occupy the slot
        let code = ctl.exec_stream_bulk_ctl(&bulk, "y", b"", |_| true).unwrap();
        assert_eq!(code, EXIT_CHANNEL_REJECTED, "cap 1 must reject the second exec");
        assert_eq!(bulk.active_subchannels(), 1, "only the in-flight sub remains");
        assert_eq!(h.join().unwrap(), 0);
        assert_eq!(bulk.active_subchannels(), 0);
    }

    #[test]
    fn bulk_conn_death_cancels_stream_and_frees_slot() {
        let kp = KeyPair::generate(26);
        let mut ak = AuthorizedKeys::new();
        ak.add(AuthorizedKey {
            fingerprint: kp.fingerprint(),
            force_command: Some("/drip".into()),
            options: vec![],
            comment: String::new(),
        });
        let stopped_early = Arc::new(AtomicBool::new(false));
        let st = stopped_early.clone();
        let dripper: Arc<dyn CommandHandler> = Arc::new(
            move |_c: &str, _o: &str, _i: &[u8], out: &mut dyn FnMut(&[u8]) -> Result<()>| {
                for _ in 0..50 {
                    std::thread::sleep(Duration::from_millis(10));
                    if out(b"tok;").is_err() {
                        st.store(true, Ordering::SeqCst);
                        return 1;
                    }
                }
                0
            },
        );
        let server = SshServer::start_with(
            ak,
            vec![kp.clone()],
            vec![("/drip".into(), dripper)],
            SshServerConfig { max_sessions: 1, ..Default::default() },
        )
        .unwrap();
        let addr = server.addr.to_string();
        let ctl = SshClient::connect(&addr, &kp).unwrap();
        let bulk = BulkChannel::connect(&addr, &kp, 3).unwrap();
        let mut seen = 0usize;
        let res = ctl.exec_stream_bulk_ctl(&bulk, "x", b"", |_| {
            seen += 1;
            if seen == 3 {
                // Sever the bulk TCP connection under the stream.
                assert!(server.kill_session(1), "bulk session index");
            }
            true
        });
        assert!(res.is_err(), "bulk death must surface as an error: {res:?}");
        assert!(!bulk.is_alive());
        // The server cancelled the orphaned handler (slot freed).
        let deadline = Instant::now() + Duration::from_secs(3);
        while !stopped_early.load(Ordering::SeqCst) {
            assert!(Instant::now() < deadline, "handler kept streaming to a dead lane");
            std::thread::sleep(Duration::from_millis(10));
        }
        // Control lane survives; a classic exec still works (slot is free).
        let deadline = Instant::now() + Duration::from_secs(3);
        loop {
            let code = ctl.exec("z", b"").unwrap().exit_code;
            if code == 1 || code == 0 {
                break; // dripper exits 1 after its failed write
            }
            assert!(Instant::now() < deadline, "MaxSessions slot never released");
            std::thread::sleep(Duration::from_millis(25));
        }
    }

    /// Reader that dribbles bytes in caller-chosen step sizes, so frames
    /// split across arbitrarily small reads.
    struct SplitReader {
        data: Vec<u8>,
        pos: usize,
        steps: Vec<usize>,
        i: usize,
    }

    impl Read for SplitReader {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if self.pos >= self.data.len() {
                return Ok(0);
            }
            let step = self.steps[self.i % self.steps.len()].max(1);
            self.i += 1;
            let n = step.min(buf.len()).min(self.data.len() - self.pos);
            buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    #[test]
    fn prop_bulk_frame_framing_roundtrips() {
        use crate::prop_assert;
        use crate::util::prop::run_prop;
        run_prop("bulk_frame_framing", 0xB01D, 60, |rng| {
            let kp = KeyPair::generate(31);
            let cn = [3u8; 16];
            let sn = [4u8; 16];
            let mut enc = kp.derive_session(&cn, &sn, true);
            let mut dec = kp.derive_session(&cn, &sn, true);
            // Sizes stressing empty, small, and >64KiB (past the pool cap).
            let size = match rng.below(3) {
                0 => 0,
                1 => rng.below(2048) as usize,
                _ => 64 * 1024 + rng.below(100_000) as usize,
            };
            let payload: Vec<u8> = (0..size).map(|i| (rng.below(256) ^ i as u64) as u8).collect();
            let ty = (7 + rng.below(5)) as u8; // the bulk frame types
            let chan = rng.below(u32::MAX as u64) as u32;
            let wire = encode_frame(&mut enc, ty, chan, &payload);
            let mut steps = Vec::new();
            for _ in 0..8 {
                steps.push(1 + rng.below(4096) as usize);
            }
            let mut r = SplitReader { data: wire, pos: 0, steps, i: 0 };
            let (ty2, chan2, got) = decode_frame(&mut r, &mut dec)
                .map_err(|e| format!("decode failed (size={size}): {e}"))?;
            prop_assert!(ty2 == ty, "type mismatch: {ty2} != {ty}");
            prop_assert!(chan2 == chan, "chan mismatch: {chan2} != {chan}");
            prop_assert!(&got[..] == &payload[..], "payload mismatch at size {size}");
            Ok(())
        });
    }

    #[test]
    fn streaming_chunks_arrive_incrementally() {
        let kp = KeyPair::generate(17);
        let mut ak = AuthorizedKeys::new();
        ak.add(AuthorizedKey {
            fingerprint: kp.fingerprint(),
            force_command: Some("/ci".into()),
            options: vec![],
            comment: String::new(),
        });
        let streamer: Arc<dyn CommandHandler> = Arc::new(
            |_c: &str, _o: &str, _i: &[u8], out: &mut dyn FnMut(&[u8]) -> Result<()>| {
                for i in 0..10 {
                    if out(format!("tok{i};").as_bytes()).is_err() {
                        return 1;
                    }
                }
                0
            },
        );
        let server =
            SshServer::start(ak, vec![kp.clone()], vec![("/ci".into(), streamer)]).unwrap();
        let client = SshClient::connect(&server.addr.to_string(), &kp).unwrap();
        let mut chunks = Vec::new();
        let code = client
            .exec_stream("anything", b"", |c| chunks.push(String::from_utf8_lossy(c).into_owned()))
            .unwrap();
        assert_eq!(code, 0);
        assert_eq!(chunks.len(), 10);
        assert_eq!(chunks[0], "tok0;");
    }
}
