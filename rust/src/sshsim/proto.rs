//! SSH-sim wire protocol: handshake, multiplexed channels, keepalives.
//!
//! One TCP connection carries many concurrent `exec` channels (the paper's
//! HPC Proxy multiplexes every inference request plus a 5-second keepalive
//! over a single persistent SSH connection — Table 2's ~200 RPS SSH ceiling
//! is this serialization). Frames are sealed by [`SessionCrypto`].
//!
//! Frame plaintext layout: `type(1) | channel(4, LE) | payload`.
//!
//! The ForceCommand enforcement point is in [`SshServer`]: after
//! authentication the requested command is *replaced* by the
//! `authorized_keys` `command=` value; the request only survives as the
//! `SSH_ORIGINAL_COMMAND` argument to the handler — byte-for-byte OpenSSH
//! semantics, and the paper's circuit breaker.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::crypto::{KeyPair, SessionCrypto};
use super::AuthorizedKeys;
use crate::util::clock::{Clock, WallClock};

const FRAME_EXEC: u8 = 0;
const FRAME_DATA: u8 = 1;
const FRAME_EOF: u8 = 2;
const FRAME_EXIT: u8 = 3;
const FRAME_PING: u8 = 4;
const FRAME_PONG: u8 = 5;
/// Client-initiated channel abandonment (OpenSSH `SSH_MSG_CHANNEL_CLOSE`):
/// the server stops the handler's output and releases the channel's
/// `MaxSessions` slot as soon as the handler returns.
const FRAME_CLOSE: u8 = 6;

const MAX_FRAME: usize = 16 * 1024 * 1024;

/// Exit code reported when the server refuses to open another channel on a
/// connection that is already at `max_sessions` (OpenSSH surfaces the same
/// condition as "channel open failed").
pub const EXIT_CHANNEL_REJECTED: i32 = 254;

/// Pseudo exit code returned by `exec_stream_ctl` when the *consumer*
/// abandoned the channel (CHANNEL_CLOSE sent); the real remote exit code
/// never arrives because the channel is already gone.
pub const EXIT_CANCELLED: i32 = 253;

/// What a command execution produces.
#[derive(Debug, Clone)]
pub struct ExecReply {
    pub exit_code: i32,
    pub stdout: Vec<u8>,
}

/// Streaming chunk delivered to `exec_stream` consumers.
#[derive(Debug)]
pub enum StreamChunk {
    Data(Vec<u8>),
    Exit(i32),
}

/// Server-side command implementation.
///
/// `command` is the command line actually being run (the ForceCommand when
/// one is pinned); `original_command` is what the client requested —
/// `SSH_ORIGINAL_COMMAND` in OpenSSH terms. `stdin` is the full request
/// body; `out` streams stdout chunks back. Returns the exit code.
pub trait CommandHandler: Send + Sync {
    fn exec(
        &self,
        command: &str,
        original_command: &str,
        stdin: &[u8],
        out: &mut dyn FnMut(&[u8]) -> Result<()>,
    ) -> i32;
}

impl<F> CommandHandler for F
where
    F: Fn(&str, &str, &[u8], &mut dyn FnMut(&[u8]) -> Result<()>) -> i32 + Send + Sync,
{
    fn exec(
        &self,
        command: &str,
        original_command: &str,
        stdin: &[u8],
        out: &mut dyn FnMut(&[u8]) -> Result<()>,
    ) -> i32 {
        self(command, original_command, stdin, out)
    }
}

// ---------------------------------------------------------------------------
// Framing helpers
// ---------------------------------------------------------------------------

fn write_frame(
    w: &mut (impl Write + ?Sized),
    crypto: &mut SessionCrypto,
    ty: u8,
    chan: u32,
    payload: &[u8],
) -> Result<()> {
    let mut plain = Vec::with_capacity(payload.len() + 5);
    plain.push(ty);
    plain.extend_from_slice(&chan.to_le_bytes());
    plain.extend_from_slice(payload);
    let sealed = crypto.seal(&plain);
    w.write_all(&(sealed.len() as u32).to_le_bytes())?;
    w.write_all(&sealed)?;
    w.flush()?;
    Ok(())
}

fn read_frame(r: &mut impl Read, crypto: &mut SessionCrypto) -> Result<(u8, u32, Vec<u8>)> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        bail!("oversized frame {len}");
    }
    let mut sealed = vec![0u8; len];
    r.read_exact(&mut sealed)?;
    let plain = crypto.open(&sealed).map_err(|e| anyhow!(e))?;
    if plain.len() < 5 {
        bail!("short frame");
    }
    let ty = plain[0];
    let chan = u32::from_le_bytes([plain[1], plain[2], plain[3], plain[4]]);
    Ok((ty, chan, plain[5..].to_vec()))
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Per-server metrics exposed to the monitoring layer.
#[derive(Default)]
pub struct SshServerStats {
    pub sessions_accepted: AtomicU64,
    pub sessions_rejected: AtomicU64,
    pub execs: AtomicU64,
    pub pings: AtomicU64,
    pub forced_commands: AtomicU64,
    /// Channel opens refused because a connection hit `max_sessions`.
    pub channel_rejections: AtomicU64,
    /// Client-initiated CHANNEL_CLOSE frames received (cancelled channels).
    pub channels_cancelled: AtomicU64,
}

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct SshServerConfig {
    /// Maximum concurrent exec channels per connection, like OpenSSH
    /// `MaxSessions`. `0` = unlimited (the seed behaviour).
    pub max_sessions: usize,
}

impl Default for SshServerConfig {
    fn default() -> SshServerConfig {
        SshServerConfig { max_sessions: 0 }
    }
}

/// The sshd of the HPC service node.
pub struct SshServer {
    pub addr: std::net::SocketAddr,
    pub stats: Arc<SshServerStats>,
    stop: Arc<AtomicBool>,
    sessions: Arc<Mutex<Vec<TcpStream>>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

struct ServerShared {
    authorized: AuthorizedKeys,
    /// Host-side key material (the functional account's keys).
    keys: BTreeMap<String, KeyPair>,
    /// command path (first token) -> handler.
    handlers: BTreeMap<String, Arc<dyn CommandHandler>>,
    stats: Arc<SshServerStats>,
    cfg: SshServerConfig,
}

impl SshServer {
    /// Start an sshd on an ephemeral port with default config (no
    /// per-connection session cap).
    ///
    /// `keys` must contain the key material for every fingerprint in
    /// `authorized`; `handlers` maps command paths (the first whitespace
    /// token of the resolved command line) to implementations.
    pub fn start(
        authorized: AuthorizedKeys,
        keys: Vec<KeyPair>,
        handlers: Vec<(String, Arc<dyn CommandHandler>)>,
    ) -> Result<SshServer> {
        SshServer::start_with(authorized, keys, handlers, SshServerConfig::default())
    }

    /// Start an sshd with explicit config (e.g. a `MaxSessions` cap).
    pub fn start_with(
        authorized: AuthorizedKeys,
        keys: Vec<KeyPair>,
        handlers: Vec<(String, Arc<dyn CommandHandler>)>,
        cfg: SshServerConfig,
    ) -> Result<SshServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stats = Arc::new(SshServerStats::default());
        let shared = Arc::new(ServerShared {
            authorized,
            keys: keys.into_iter().map(|k| (k.fingerprint(), k)).collect(),
            handlers: handlers.into_iter().collect(),
            stats: stats.clone(),
            cfg,
        });
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let sessions: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let sessions2 = sessions.clone();
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if let Ok(clone) = stream.try_clone() {
                            sessions2.lock().unwrap().push(clone);
                        }
                        let sh = shared.clone();
                        std::thread::spawn(move || {
                            let _ = serve_session(stream, sh);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(SshServer { addr, stats, stop, sessions, handle: Some(handle) })
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Kill live sessions so clients observe the outage immediately.
        for s in self.sessions.lock().unwrap().drain(..) {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    /// Hard-close one accepted connection (index in accept order) without
    /// stopping the server — simulates a single pool member's link dying
    /// while the others stay up.
    pub fn kill_session(&self, index: usize) -> bool {
        let sessions = self.sessions.lock().unwrap();
        match sessions.get(index) {
            Some(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
                true
            }
            None => false,
        }
    }

    /// Number of TCP connections accepted so far (dead ones included).
    pub fn session_count(&self) -> usize {
        self.sessions.lock().unwrap().len()
    }
}

impl Drop for SshServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_session(mut stream: TcpStream, shared: Arc<ServerShared>) -> Result<()> {
    stream.set_nodelay(true)?;
    // --- handshake ---
    let mut fp_buf = [0u8; 64];
    stream.read_exact(&mut fp_buf)?;
    let fingerprint = std::str::from_utf8(&fp_buf)?.to_string();
    let mut client_nonce = [0u8; 16];
    stream.read_exact(&mut client_nonce)?;

    let (Some(entry), Some(key)) =
        (shared.authorized.lookup(&fingerprint), shared.keys.get(&fingerprint))
    else {
        shared.stats.sessions_rejected.fetch_add(1, Ordering::Relaxed);
        let _ = stream.write_all(&[0u8]); // reject
        return Ok(());
    };
    let entry = entry.clone();

    // Server nonce from OS entropy-ish source (time + addr hash is enough
    // for the simulation; uniqueness is what matters for CTR keys).
    let mut server_nonce = [0u8; 16];
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap_or_default();
    server_nonce[..8].copy_from_slice(&t.as_nanos().to_le_bytes()[..8]);
    server_nonce[8..].copy_from_slice(&(&stream as *const _ as u64).to_le_bytes());
    stream.write_all(&[1u8])?; // accept
    stream.write_all(&server_nonce)?;

    let mut proof = [0u8; 32];
    stream.read_exact(&mut proof)?;
    if proof != key.prove(&client_nonce, &server_nonce) {
        shared.stats.sessions_rejected.fetch_add(1, Ordering::Relaxed);
        return Ok(());
    }
    shared.stats.sessions_accepted.fetch_add(1, Ordering::Relaxed);

    let mut recv_crypto = key.derive_session(&client_nonce, &server_nonce, false);
    // Writer shares the socket: split send/recv crypto states.
    let send_crypto = key.derive_session(&client_nonce, &server_nonce, false);
    let writer = Arc::new(Mutex::new((stream.try_clone()?, send_crypto)));

    // Per-channel stdin accumulators.
    let mut stdin_bufs: BTreeMap<u32, Vec<u8>> = BTreeMap::new();
    // Concurrent exec channels on THIS connection (MaxSessions accounting):
    // counted from channel open (EXEC) until the handler thread finishes.
    let inflight = Arc::new(AtomicUsize::new(0));
    // Channels whose client sent CHANNEL_CLOSE while a handler was running:
    // the flag makes the handler's next output write fail, which is how the
    // cancellation reaches CommandHandler implementations.
    let cancels: Arc<Mutex<BTreeMap<u32, Arc<AtomicBool>>>> =
        Arc::new(Mutex::new(BTreeMap::new()));

    loop {
        let (ty, chan, payload) = match read_frame(&mut stream, &mut recv_crypto) {
            Ok(f) => f,
            Err(_) => break, // disconnect
        };
        match ty {
            FRAME_PING => {
                shared.stats.pings.fetch_add(1, Ordering::Relaxed);
                let w = writer.clone();
                let mut g = w.lock().unwrap();
                let (ref mut sock, ref mut crypto) = *g;
                let _ = write_frame(sock, crypto, FRAME_PONG, chan, &payload);
            }
            FRAME_EXEC => {
                // *** MaxSessions: refuse the channel open outright. ***
                let cap = shared.cfg.max_sessions;
                if cap > 0 && inflight.load(Ordering::SeqCst) >= cap {
                    shared.stats.channel_rejections.fetch_add(1, Ordering::Relaxed);
                    let mut g = writer.lock().unwrap();
                    let (ref mut sock, ref mut crypto) = *g;
                    let _ = write_frame(
                        sock,
                        crypto,
                        FRAME_DATA,
                        chan,
                        format!("sshsim: channel open failed: MaxSessions {cap} reached\n")
                            .as_bytes(),
                    );
                    let _ = write_frame(
                        sock,
                        crypto,
                        FRAME_EXIT,
                        chan,
                        &(EXIT_CHANNEL_REJECTED as u32).to_le_bytes(),
                    );
                    continue;
                }
                inflight.fetch_add(1, Ordering::SeqCst);
                stdin_bufs.insert(chan, payload);
            }
            FRAME_DATA => {
                if let Some(buf) = stdin_bufs.get_mut(&chan) {
                    // EXEC payload holds the command; stdin appends after a
                    // NUL separator written by the client.
                    buf.extend_from_slice(&payload);
                }
            }
            FRAME_EOF => {
                // Request complete: resolve + dispatch.
                let Some(buf) = stdin_bufs.remove(&chan) else { continue };
                let inflight = inflight.clone();
                let sep = buf.iter().position(|&b| b == 0).unwrap_or(buf.len());
                let requested = String::from_utf8_lossy(&buf[..sep]).into_owned();
                let stdin = if sep < buf.len() { buf[sep + 1..].to_vec() } else { Vec::new() };

                // *** The ForceCommand circuit breaker. ***
                let (command, original) = match &entry.force_command {
                    Some(forced) => {
                        shared.stats.forced_commands.fetch_add(1, Ordering::Relaxed);
                        (forced.clone(), requested)
                    }
                    None => (requested.clone(), requested),
                };
                shared.stats.execs.fetch_add(1, Ordering::Relaxed);

                let path = command.split_whitespace().next().unwrap_or("").to_string();
                let handler = shared.handlers.get(&path).cloned();
                let w = writer.clone();
                let cancelled = Arc::new(AtomicBool::new(false));
                cancels.lock().unwrap().insert(chan, cancelled.clone());
                let cancels_map = cancels.clone();
                std::thread::spawn(move || {
                    let send =
                        |ty: u8, payload: &[u8]| -> Result<()> {
                            if cancelled.load(Ordering::SeqCst) {
                                bail!("channel {chan} closed by client");
                            }
                            let mut g = w.lock().unwrap();
                            let (ref mut sock, ref mut crypto) = *g;
                            write_frame(sock, crypto, ty, chan, payload)
                        };
                    let code = match handler {
                        Some(h) => {
                            let mut out =
                                |chunk: &[u8]| -> Result<()> { send(FRAME_DATA, chunk) };
                            h.exec(&command, &original, &stdin, &mut out)
                        }
                        None => {
                            let _ = send(
                                FRAME_DATA,
                                format!("sshsim: {path}: command not found\n").as_bytes(),
                            );
                            127
                        }
                    };
                    // On a cancelled channel the EXIT frame is suppressed
                    // (the client already forgot the channel); the send
                    // closure's flag check does that for us.
                    let _ = send(FRAME_EXIT, &(code as u32).to_le_bytes());
                    cancels_map.lock().unwrap().remove(&chan);
                    inflight.fetch_sub(1, Ordering::SeqCst);
                });
            }
            FRAME_CLOSE => {
                shared.stats.channels_cancelled.fetch_add(1, Ordering::Relaxed);
                if stdin_bufs.remove(&chan).is_some() {
                    // Closed before EOF ever dispatched a handler: release
                    // the MaxSessions slot taken at EXEC.
                    inflight.fetch_sub(1, Ordering::SeqCst);
                } else if let Some(flag) = cancels.lock().unwrap().get(&chan) {
                    // Handler running: fail its next output write.
                    flag.store(true, Ordering::SeqCst);
                }
            }
            _ => {}
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Client side of the persistent SSH connection (held by the HPC Proxy).
pub struct SshClient {
    writer: Arc<Mutex<(TcpStream, SessionCrypto)>>,
    channels: Arc<Mutex<BTreeMap<u32, Sender<StreamChunk>>>>,
    pong: Arc<Mutex<BTreeMap<u32, Sender<()>>>>,
    next_chan: AtomicU32,
    dead: Arc<AtomicBool>,
    /// Emulated serialized wire time per frame. Loopback TCP is far faster
    /// than the paper's ESX↔HPC link + OpenSSH channel costs; benches set
    /// this (calibrated against Table 1's measured SSH leg) to reproduce
    /// the single-connection ~200 RPS ceiling of Table 2. Zero by default.
    frame_delay: Duration,
    /// Where `frame_delay` is charged: the wall clock by default; a
    /// `SimClock` makes wire time advance virtual microseconds instead.
    clock: Arc<dyn Clock>,
}

impl SshClient {
    /// Connect and authenticate with `key`.
    pub fn connect(addr: &str, key: &KeyPair) -> Result<SshClient> {
        SshClient::connect_with(addr, key, Duration::ZERO)
    }

    /// Connect with an emulated per-frame wire delay (see `frame_delay`).
    pub fn connect_with(addr: &str, key: &KeyPair, frame_delay: Duration) -> Result<SshClient> {
        SshClient::connect_with_clock(addr, key, frame_delay, WallClock::new())
    }

    /// Like [`SshClient::connect_with`], but wire-time charges go to the
    /// injected clock (virtual microseconds under a `SimClock`).
    pub fn connect_with_clock(
        addr: &str,
        key: &KeyPair,
        frame_delay: Duration,
        clock: Arc<dyn Clock>,
    ) -> Result<SshClient> {
        let mut stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true)?;
        // --- handshake ---
        stream.write_all(key.fingerprint().as_bytes())?;
        let mut client_nonce = [0u8; 16];
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap_or_default();
        client_nonce[..8].copy_from_slice(&t.as_nanos().to_le_bytes()[..8]);
        client_nonce[8..].copy_from_slice(&std::process::id().to_le_bytes().repeat(4)[..8]);
        stream.write_all(&client_nonce)?;

        let mut accept = [0u8; 1];
        stream.read_exact(&mut accept)?;
        if accept[0] != 1 {
            bail!("server rejected key {}", key.fingerprint());
        }
        let mut server_nonce = [0u8; 16];
        stream.read_exact(&mut server_nonce)?;
        stream.write_all(&key.prove(&client_nonce, &server_nonce))?;

        let send_crypto = key.derive_session(&client_nonce, &server_nonce, true);
        let mut recv_crypto = key.derive_session(&client_nonce, &server_nonce, true);

        let writer = Arc::new(Mutex::new((stream.try_clone()?, send_crypto)));
        let channels: Arc<Mutex<BTreeMap<u32, Sender<StreamChunk>>>> =
            Arc::new(Mutex::new(BTreeMap::new()));
        let pong: Arc<Mutex<BTreeMap<u32, Sender<()>>>> = Arc::new(Mutex::new(BTreeMap::new()));
        let dead = Arc::new(AtomicBool::new(false));

        // Reader thread: route frames to channel receivers.
        let channels2 = channels.clone();
        let pong2 = pong.clone();
        let dead2 = dead.clone();
        std::thread::spawn(move || {
            let mut stream = stream;
            loop {
                match read_frame(&mut stream, &mut recv_crypto) {
                    Ok((ty, chan, payload)) => match ty {
                        FRAME_DATA => {
                            if let Some(tx) = channels2.lock().unwrap().get(&chan) {
                                let _ = tx.send(StreamChunk::Data(payload));
                            }
                        }
                        FRAME_EXIT => {
                            let code = i32::from_le_bytes([
                                payload[0], payload[1], payload[2], payload[3],
                            ]);
                            if let Some(tx) = channels2.lock().unwrap().remove(&chan) {
                                let _ = tx.send(StreamChunk::Exit(code));
                            }
                        }
                        FRAME_PONG => {
                            if let Some(tx) = pong2.lock().unwrap().remove(&chan) {
                                let _ = tx.send(());
                            }
                        }
                        _ => {}
                    },
                    Err(_) => {
                        dead2.store(true, Ordering::SeqCst);
                        // Wake all waiters by dropping their senders.
                        channels2.lock().unwrap().clear();
                        pong2.lock().unwrap().clear();
                        break;
                    }
                }
            }
        });

        Ok(SshClient { writer, channels, pong, next_chan: AtomicU32::new(1), dead, frame_delay, clock })
    }

    pub fn is_alive(&self) -> bool {
        !self.dead.load(Ordering::SeqCst)
    }

    fn send(&self, ty: u8, chan: u32, payload: &[u8]) -> Result<()> {
        if !self.is_alive() {
            bail!("ssh connection is down");
        }
        let mut g = self.writer.lock().unwrap();
        if !self.frame_delay.is_zero() {
            // Serialized wire time: held under the writer lock on purpose —
            // one connection, one wire (the paper's SSH bottleneck).
            self.clock.sleep(self.frame_delay);
        }
        let (ref mut sock, ref mut crypto) = *g;
        write_frame(sock, crypto, ty, chan, payload).map_err(|e| {
            self.dead.store(true, Ordering::SeqCst);
            e
        })
    }

    /// Write several frames of one channel under a single writer-lock
    /// acquisition: a pipelined exec leaves EXEC+DATA+EOF back-to-back on
    /// the wire instead of letting other channels interleave (and pay the
    /// lock) between each frame.
    fn send_pipelined(&self, chan: u32, frames: &[(u8, &[u8])]) -> Result<()> {
        if !self.is_alive() {
            bail!("ssh connection is down");
        }
        let mut g = self.writer.lock().unwrap();
        if !self.frame_delay.is_zero() {
            // Serialized wire time, one slot per frame (see `send`).
            self.clock.sleep(self.frame_delay * frames.len() as u32);
        }
        let (ref mut sock, ref mut crypto) = *g;
        for (ty, payload) in frames {
            write_frame(sock, crypto, *ty, chan, payload).map_err(|e| {
                self.dead.store(true, Ordering::SeqCst);
                e
            })?;
        }
        Ok(())
    }

    fn open_channel(&self) -> (u32, Receiver<StreamChunk>) {
        let chan = self.next_chan.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = channel();
        self.channels.lock().unwrap().insert(chan, tx);
        (chan, rx)
    }

    /// Exec channels currently open (in-flight requests) — the load signal
    /// the proxy pool uses for least-loaded placement.
    pub fn active_channels(&self) -> usize {
        self.channels.lock().unwrap().len()
    }

    /// Execute `command` with `stdin`, streaming stdout chunks to
    /// `on_chunk`; returns the exit code.
    pub fn exec_stream(
        &self,
        command: &str,
        stdin: &[u8],
        mut on_chunk: impl FnMut(&[u8]),
    ) -> Result<i32> {
        self.exec_stream_ctl(command, stdin, |chunk| {
            on_chunk(chunk);
            true
        })
    }

    /// Cancellable exec: like [`exec_stream`], but `on_chunk` returns
    /// whether to keep consuming. Returning `false` sends CHANNEL_CLOSE,
    /// drops the channel from this connection's accounting immediately
    /// (the lane is placeable again before the server even reacts), and
    /// returns [`EXIT_CANCELLED`].
    pub fn exec_stream_ctl(
        &self,
        command: &str,
        stdin: &[u8],
        mut on_chunk: impl FnMut(&[u8]) -> bool,
    ) -> Result<i32> {
        let (chan, rx) = self.open_channel();
        // EXEC payload = command; stdin travels as DATA after a NUL marker.
        let mut body = vec![0u8];
        body.extend_from_slice(stdin);
        let frames: [(u8, &[u8]); 3] =
            [(FRAME_EXEC, command.as_bytes()), (FRAME_DATA, &body), (FRAME_EOF, &[])];
        if let Err(e) = self.send_pipelined(chan, &frames) {
            self.channels.lock().unwrap().remove(&chan);
            return Err(e);
        }
        loop {
            match rx.recv_timeout(Duration::from_secs(60)) {
                Ok(StreamChunk::Data(d)) => {
                    if !on_chunk(&d) {
                        self.channels.lock().unwrap().remove(&chan);
                        // Best-effort: a dead connection already freed the
                        // server side, so the close frame may not go out.
                        let _ = self.send(FRAME_CLOSE, chan, &[]);
                        return Ok(EXIT_CANCELLED);
                    }
                }
                Ok(StreamChunk::Exit(code)) => return Ok(code),
                Err(_) => {
                    self.channels.lock().unwrap().remove(&chan);
                    // Same ghost-generation hazard as an explicit abandon:
                    // without a close the server handler keeps its
                    // MaxSessions slot and keeps generating for nobody.
                    let _ = self.send(FRAME_CLOSE, chan, &[]);
                    bail!("ssh exec timed out or connection lost");
                }
            }
        }
    }

    /// Execute and collect stdout.
    pub fn exec(&self, command: &str, stdin: &[u8]) -> Result<ExecReply> {
        let mut stdout = Vec::new();
        let exit_code = self.exec_stream(command, stdin, |chunk| {
            stdout.extend_from_slice(chunk);
        })?;
        Ok(ExecReply { exit_code, stdout })
    }

    /// Keepalive ping; returns the round-trip time.
    pub fn ping(&self) -> Result<Duration> {
        let chan = self.next_chan.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = channel();
        self.pong.lock().unwrap().insert(chan, tx);
        let start = Instant::now();
        self.send(FRAME_PING, chan, &[])?;
        rx.recv_timeout(Duration::from_secs(10))
            .map_err(|_| anyhow!("ping timeout"))?;
        Ok(start.elapsed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sshsim::AuthorizedKey;

    fn echo_handler() -> Arc<dyn CommandHandler> {
        Arc::new(
            |command: &str,
             original: &str,
             stdin: &[u8],
             out: &mut dyn FnMut(&[u8]) -> Result<()>| {
                let _ = out(format!("cmd={command}\n").as_bytes());
                let _ = out(format!("orig={original}\n").as_bytes());
                let _ = out(b"stdin=");
                let _ = out(stdin);
                0
            },
        )
    }

    fn forced_server(kp: &KeyPair) -> SshServer {
        let mut ak = AuthorizedKeys::new();
        ak.add(AuthorizedKey {
            fingerprint: kp.fingerprint(),
            force_command: Some("/opt/saia/cloud_interface".into()),
            options: vec!["restrict".into()],
            comment: "esx".into(),
        });
        SshServer::start(
            ak,
            vec![kp.clone()],
            vec![("/opt/saia/cloud_interface".into(), echo_handler())],
        )
        .unwrap()
    }

    #[test]
    fn exec_roundtrip_with_force_command() {
        let kp = KeyPair::generate(11);
        let server = forced_server(&kp);
        let client = SshClient::connect(&server.addr.to_string(), &kp).unwrap();
        // The client asks for an arbitrary (malicious) command...
        let reply = client.exec("rm -rf / --no-preserve-root", b"PAYLOAD").unwrap();
        let text = String::from_utf8_lossy(&reply.stdout);
        // ...but the pinned command runs, and the request is demoted to
        // SSH_ORIGINAL_COMMAND.
        assert!(text.contains("cmd=/opt/saia/cloud_interface"), "{text}");
        assert!(text.contains("orig=rm -rf / --no-preserve-root"), "{text}");
        assert!(text.contains("stdin=PAYLOAD"), "{text}");
        assert_eq!(reply.exit_code, 0);
        assert_eq!(server.stats.forced_commands.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn unauthorized_key_rejected() {
        let kp = KeyPair::generate(12);
        let server = forced_server(&kp);
        let rogue = KeyPair::generate(666);
        let err = SshClient::connect(&server.addr.to_string(), &rogue);
        assert!(err.is_err());
        assert_eq!(server.stats.sessions_rejected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn no_handler_means_exit_127() {
        let kp = KeyPair::generate(13);
        let mut ak = AuthorizedKeys::new();
        ak.add(AuthorizedKey {
            fingerprint: kp.fingerprint(),
            force_command: None,
            options: vec![],
            comment: String::new(),
        });
        let server = SshServer::start(ak, vec![kp.clone()], vec![]).unwrap();
        let client = SshClient::connect(&server.addr.to_string(), &kp).unwrap();
        let reply = client.exec("/bin/bash -c evil", b"").unwrap();
        assert_eq!(reply.exit_code, 127);
        assert!(String::from_utf8_lossy(&reply.stdout).contains("command not found"));
    }

    #[test]
    fn concurrent_execs_multiplex_one_connection() {
        let kp = KeyPair::generate(14);
        let server = forced_server(&kp);
        let client = Arc::new(SshClient::connect(&server.addr.to_string(), &kp).unwrap());
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let c = client.clone();
                std::thread::spawn(move || {
                    for j in 0..5 {
                        let body = format!("req-{i}-{j}");
                        let reply = c.exec("x", body.as_bytes()).unwrap();
                        assert!(
                            String::from_utf8_lossy(&reply.stdout)
                                .contains(&format!("stdin={body}")),
                        );
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.stats.execs.load(Ordering::Relaxed), 40);
        assert_eq!(server.stats.sessions_accepted.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn ping_keepalive() {
        let kp = KeyPair::generate(15);
        let server = forced_server(&kp);
        let client = SshClient::connect(&server.addr.to_string(), &kp).unwrap();
        for _ in 0..3 {
            let rtt = client.ping().unwrap();
            assert!(rtt < Duration::from_secs(1));
        }
        assert_eq!(server.stats.pings.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn server_death_detected() {
        let kp = KeyPair::generate(16);
        let mut server = forced_server(&kp);
        let client = SshClient::connect(&server.addr.to_string(), &kp).unwrap();
        assert!(client.is_alive());
        server.stop();
        // Next operation fails and marks the connection dead.
        std::thread::sleep(Duration::from_millis(50));
        let _ = client.ping();
        let _ = client.ping();
        assert!(!client.is_alive() || client.ping().is_err());
    }

    fn slow_handler(ms: u64) -> Arc<dyn CommandHandler> {
        Arc::new(
            move |_c: &str,
                  _o: &str,
                  _i: &[u8],
                  out: &mut dyn FnMut(&[u8]) -> Result<()>| {
                std::thread::sleep(Duration::from_millis(ms));
                let _ = out(b"done");
                0
            },
        )
    }

    #[test]
    fn max_sessions_cap_rejects_excess_channels() {
        let kp = KeyPair::generate(18);
        let mut ak = AuthorizedKeys::new();
        ak.add(AuthorizedKey {
            fingerprint: kp.fingerprint(),
            force_command: Some("/slow".into()),
            options: vec![],
            comment: String::new(),
        });
        let server = SshServer::start_with(
            ak,
            vec![kp.clone()],
            vec![("/slow".into(), slow_handler(200))],
            SshServerConfig { max_sessions: 2 },
        )
        .unwrap();
        let client = Arc::new(SshClient::connect(&server.addr.to_string(), &kp).unwrap());
        let handles: Vec<_> = (0..6)
            .map(|_| {
                let c = client.clone();
                std::thread::spawn(move || c.exec("x", b"").unwrap().exit_code)
            })
            .collect();
        let codes: Vec<i32> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(codes.iter().any(|&c| c == 0), "some execs must run: {codes:?}");
        assert!(
            codes.iter().any(|&c| c == EXIT_CHANNEL_REJECTED),
            "cap 2 with 6 concurrent execs must reject: {codes:?}"
        );
        assert!(server.stats.channel_rejections.load(Ordering::Relaxed) >= 1);
        // The connection itself survives rejections.
        assert_eq!(client.exec("again", b"").unwrap().exit_code, 0);
    }

    #[test]
    fn active_channels_tracks_inflight_execs() {
        let kp = KeyPair::generate(19);
        let mut ak = AuthorizedKeys::new();
        ak.add(AuthorizedKey {
            fingerprint: kp.fingerprint(),
            force_command: Some("/slow".into()),
            options: vec![],
            comment: String::new(),
        });
        let server = SshServer::start(ak, vec![kp.clone()], vec![("/slow".into(), slow_handler(150))])
            .unwrap();
        let client = Arc::new(SshClient::connect(&server.addr.to_string(), &kp).unwrap());
        assert_eq!(client.active_channels(), 0);
        let c = client.clone();
        let h = std::thread::spawn(move || c.exec("x", b"").unwrap());
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(client.active_channels(), 1, "exec in flight");
        h.join().unwrap();
        assert_eq!(client.active_channels(), 0, "drained after exit");
    }

    #[test]
    fn channel_close_stops_server_handler_and_frees_accounting() {
        let kp = KeyPair::generate(20);
        let mut ak = AuthorizedKeys::new();
        ak.add(AuthorizedKey {
            fingerprint: kp.fingerprint(),
            force_command: Some("/drip".into()),
            options: vec![],
            comment: String::new(),
        });
        // A handler that drips chunks until its output write fails.
        let emitted = Arc::new(AtomicUsize::new(0));
        let stopped_early = Arc::new(AtomicBool::new(false));
        let (em, st) = (emitted.clone(), stopped_early.clone());
        let dripper: Arc<dyn CommandHandler> = Arc::new(
            move |_c: &str, _o: &str, _i: &[u8], out: &mut dyn FnMut(&[u8]) -> Result<()>| {
                for _ in 0..50 {
                    std::thread::sleep(Duration::from_millis(10));
                    if out(b"tok;").is_err() {
                        st.store(true, Ordering::SeqCst);
                        return 1;
                    }
                    em.fetch_add(1, Ordering::SeqCst);
                }
                0
            },
        );
        let server =
            SshServer::start(ak, vec![kp.clone()], vec![("/drip".into(), dripper)]).unwrap();
        let client = SshClient::connect(&server.addr.to_string(), &kp).unwrap();

        let mut seen = 0usize;
        let code = client
            .exec_stream_ctl("x", b"", |_| {
                seen += 1;
                seen < 3 // abandon after the third chunk
            })
            .unwrap();
        assert_eq!(code, EXIT_CANCELLED);
        // Channel accounting freed immediately on the client side.
        assert_eq!(client.active_channels(), 0, "lane not released");
        // The CLOSE frame reached the server and the handler stopped.
        let deadline = Instant::now() + Duration::from_secs(3);
        while !stopped_early.load(Ordering::SeqCst) {
            assert!(Instant::now() < deadline, "server handler never noticed the close");
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(server.stats.channels_cancelled.load(Ordering::Relaxed), 1);
        let produced = emitted.load(Ordering::SeqCst);
        assert!(produced < 50, "handler ran to completion despite close: {produced}");
        // The connection itself survives: a fresh exec runs to completion.
        let reply = client.exec("again", b"").unwrap();
        assert_eq!(reply.exit_code, 0);
    }

    #[test]
    fn cancelled_channel_releases_max_sessions_slot() {
        // Cap 1: while a drip exec is in flight the cap is full; after the
        // client closes the channel the next exec must be admitted.
        let kp = KeyPair::generate(21);
        let mut ak = AuthorizedKeys::new();
        ak.add(AuthorizedKey {
            fingerprint: kp.fingerprint(),
            force_command: Some("/slow".into()),
            options: vec![],
            comment: String::new(),
        });
        let server = SshServer::start_with(
            ak,
            vec![kp.clone()],
            vec![("/slow".into(), slow_handler(400))],
            SshServerConfig { max_sessions: 1 },
        )
        .unwrap();
        let client = Arc::new(SshClient::connect(&server.addr.to_string(), &kp).unwrap());
        // First exec occupies the only session slot, then gets abandoned.
        let c = client.clone();
        let h = std::thread::spawn(move || {
            c.exec_stream_ctl("x", b"", |_| false).unwrap() // close on first chunk
        });
        assert_eq!(h.join().unwrap(), EXIT_CANCELLED);
        // The handler thread finishes within its sleep; once it does, the
        // slot is free and a new exec is admitted rather than rejected.
        let deadline = Instant::now() + Duration::from_secs(3);
        loop {
            let code = client.exec("y", b"").unwrap().exit_code;
            if code == 0 {
                break;
            }
            assert_eq!(code, EXIT_CHANNEL_REJECTED);
            assert!(Instant::now() < deadline, "MaxSessions slot never released");
            std::thread::sleep(Duration::from_millis(25));
        }
    }

    #[test]
    fn streaming_chunks_arrive_incrementally() {
        let kp = KeyPair::generate(17);
        let mut ak = AuthorizedKeys::new();
        ak.add(AuthorizedKey {
            fingerprint: kp.fingerprint(),
            force_command: Some("/ci".into()),
            options: vec![],
            comment: String::new(),
        });
        let streamer: Arc<dyn CommandHandler> = Arc::new(
            |_c: &str, _o: &str, _i: &[u8], out: &mut dyn FnMut(&[u8]) -> Result<()>| {
                for i in 0..10 {
                    if out(format!("tok{i};").as_bytes()).is_err() {
                        return 1;
                    }
                }
                0
            },
        );
        let server =
            SshServer::start(ak, vec![kp.clone()], vec![("/ci".into(), streamer)]).unwrap();
        let client = SshClient::connect(&server.addr.to_string(), &kp).unwrap();
        let mut chunks = Vec::new();
        let code = client
            .exec_stream("anything", b"", |c| chunks.push(String::from_utf8_lossy(c).into_owned()))
            .unwrap();
        assert_eq!(code, 0);
        assert_eq!(chunks.len(), 10);
        assert_eq!(chunks[0], "tok0;");
    }
}
