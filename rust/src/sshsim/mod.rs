//! SSH-shaped secure channel with ForceCommand enforcement.
//!
//! This substrate reproduces the paper's security boundary (§5.4–5.5,
//! §6.1.2): the *only* wire between the internet-facing web server and the
//! HPC cluster is an SSH connection whose key is pinned — via the
//! `authorized_keys` `command=` (ForceCommand) option — to a single
//! entrypoint, the Cloud Interface Script. A fully compromised web server
//! holding the key can still only ever invoke that one entrypoint.
//!
//! What is real here:
//! - the wire protocol: length-framed messages encrypted with AES-128-CTR
//!   and authenticated with HMAC-SHA256 (encrypt-then-MAC) under session
//!   keys derived from the key secret + fresh nonces; replay-protected by
//!   monotonic frame counters;
//! - `authorized_keys` parsing with `command=`/option semantics and the
//!   server-side enforcement point (the client's requested command is
//!   demoted to `SSH_ORIGINAL_COMMAND`, exactly like OpenSSH);
//! - channel multiplexing over one connection (the paper's HPC Proxy keeps
//!   a single persistent connection and pushes all traffic + keepalives
//!   through it — its ~200 RPS ceiling in Table 2 comes from this; the
//!   pooled proxy in [`crate::hpcproxy`] breaks that ceiling with N such
//!   connections), plus OpenSSH `MaxSessions`-style per-connection channel
//!   caps ([`SshServerConfig`]);
//! - keepalive pings (every 5 s in the paper) and reconnect detection;
//! - an opt-in dual-channel mode ([`BulkChannel`]): control traffic (exec
//!   setup, cancel, keepalive, exit status) stays on the pooled lanes while
//!   token payloads stream over dedicated bulk connections with
//!   length-prefixed binary frames — the stand-in for an SSH
//!   subsystem/port-forward data channel (DESIGN.md §Dual-channel
//!   streaming).
//!
//! What is simulated: identity. Key pairs are a 32-byte secret whose
//! "public key" is its SHA-256 fingerprint; the handshake proves possession
//! via HMAC instead of a signature. The circuit-breaker property under
//! evaluation — *server-side* command pinning — is independent of the
//! signature scheme (DESIGN.md §Substitution-ledger).

mod crypto;
mod proto;

pub use crypto::{hex, KeyPair, SessionCrypto};
pub use proto::{
    decode_frame, encode_frame, BulkChannel, CommandHandler, ExecReply, SshClient, SshServer,
    SshServerConfig, StreamChunk, EXIT_CANCELLED, EXIT_CHANNEL_REJECTED,
};
// Wire-fault source consumed by `SshServerConfig::faults`.
pub use crate::util::faults::{FrameFault, LinkFaults};

use std::collections::BTreeMap;

/// One parsed `authorized_keys` entry.
#[derive(Debug, Clone)]
pub struct AuthorizedKey {
    /// SHA-256 fingerprint of the key (hex).
    pub fingerprint: String,
    /// `command="..."` — the ForceCommand. When set, whatever the client
    /// asked to execute is replaced by this; the original request is passed
    /// to the handler as `SSH_ORIGINAL_COMMAND`.
    pub force_command: Option<String>,
    /// Options like `no-port-forwarding`, `no-pty`, `restrict`.
    pub options: Vec<String>,
    pub comment: String,
}

/// Parsed `authorized_keys` file: fingerprint -> entry.
#[derive(Debug, Clone, Default)]
pub struct AuthorizedKeys {
    entries: BTreeMap<String, AuthorizedKey>,
}

impl AuthorizedKeys {
    pub fn new() -> AuthorizedKeys {
        AuthorizedKeys::default()
    }

    pub fn add(&mut self, entry: AuthorizedKey) {
        self.entries.insert(entry.fingerprint.clone(), entry);
    }

    pub fn lookup(&self, fingerprint: &str) -> Option<&AuthorizedKey> {
        self.entries.get(fingerprint)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Parse the OpenSSH `authorized_keys` format (subset):
    ///
    /// ```text
    /// command="/usr/local/bin/cloud_interface",no-pty,restrict ssh-sim <fingerprint> <comment>
    /// ssh-sim <fingerprint> <comment>
    /// ```
    pub fn parse(text: &str) -> Result<AuthorizedKeys, String> {
        let mut out = AuthorizedKeys::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let entry = parse_entry(line).map_err(|e| format!("line {}: {e}", lineno + 1))?;
            out.add(entry);
        }
        Ok(out)
    }
}

fn parse_entry(line: &str) -> Result<AuthorizedKey, String> {
    // The options prefix (if any) ends at the first space not inside quotes.
    let (options_str, rest) = if line.starts_with("ssh-sim ") {
        ("", line)
    } else {
        let mut in_quotes = false;
        let mut split = None;
        for (i, c) in line.char_indices() {
            match c {
                '"' => in_quotes = !in_quotes,
                ' ' if !in_quotes => {
                    split = Some(i);
                    break;
                }
                _ => {}
            }
        }
        let i = split.ok_or("missing key type")?;
        (&line[..i], line[i + 1..].trim_start())
    };

    let mut parts = rest.split_whitespace();
    let keytype = parts.next().ok_or("missing key type")?;
    if keytype != "ssh-sim" {
        return Err(format!("unsupported key type {keytype}"));
    }
    let fingerprint = parts.next().ok_or("missing fingerprint")?.to_string();
    if fingerprint.len() != 64 || !fingerprint.bytes().all(|b| b.is_ascii_hexdigit()) {
        return Err("fingerprint must be 64 hex chars".into());
    }
    let comment = parts.collect::<Vec<_>>().join(" ");

    let mut force_command = None;
    let mut options = Vec::new();
    if !options_str.is_empty() {
        for opt in split_options(options_str) {
            if let Some(cmd) = opt.strip_prefix("command=") {
                let cmd = cmd.trim_matches('"');
                force_command = Some(cmd.to_string());
            } else {
                options.push(opt);
            }
        }
    }
    Ok(AuthorizedKey { fingerprint, force_command, options, comment })
}

/// Split a comma-separated option list, honouring quotes.
fn split_options(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_quotes = !in_quotes;
                cur.push(c);
            }
            ',' if !in_quotes => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_force_command_entry() {
        let kp = KeyPair::generate(1);
        let line = format!(
            "command=\"/opt/saia/cloud_interface.sh\",no-pty,no-port-forwarding,restrict ssh-sim {} esx-proxy@web01",
            kp.fingerprint()
        );
        let ak = AuthorizedKeys::parse(&line).unwrap();
        let entry = ak.lookup(&kp.fingerprint()).unwrap();
        assert_eq!(entry.force_command.as_deref(), Some("/opt/saia/cloud_interface.sh"));
        assert_eq!(entry.options, vec!["no-pty", "no-port-forwarding", "restrict"]);
        assert_eq!(entry.comment, "esx-proxy@web01");
    }

    #[test]
    fn parse_plain_entry_and_comments() {
        let kp = KeyPair::generate(2);
        let text = format!(
            "# functional account keys\n\nssh-sim {} admin@mgmt\n",
            kp.fingerprint()
        );
        let ak = AuthorizedKeys::parse(&text).unwrap();
        assert_eq!(ak.len(), 1);
        assert!(ak.lookup(&kp.fingerprint()).unwrap().force_command.is_none());
    }

    #[test]
    fn parse_command_with_spaces_and_commas() {
        let kp = KeyPair::generate(3);
        let line = format!(
            "command=\"/bin/ci --mode a,b --flag\",restrict ssh-sim {} c",
            kp.fingerprint()
        );
        let ak = AuthorizedKeys::parse(&line).unwrap();
        let entry = ak.lookup(&kp.fingerprint()).unwrap();
        assert_eq!(entry.force_command.as_deref(), Some("/bin/ci --mode a,b --flag"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(AuthorizedKeys::parse("ssh-rsa AAAA real-key").is_err());
        assert!(AuthorizedKeys::parse("ssh-sim nothex").is_err());
        assert!(AuthorizedKeys::parse("command=\"x\" ssh-sim").is_err());
    }

    #[test]
    fn unknown_fingerprint_not_found() {
        let ak = AuthorizedKeys::parse("").unwrap();
        assert!(ak.lookup(&"0".repeat(64)).is_none());
        assert!(ak.is_empty());
    }
}
