//! End-to-end validation driver (DESIGN.md §6): the full system on a real
//! workload.
//!
//! Boots the complete stack on the paper's 10-node / 40-GPU cluster
//! geometry with THREE services — the real PJRT-compiled `tiny` model plus
//! two simulated production models — then serves a batched multi-client
//! workload through the whole path and reports per-model latency,
//! throughput, and cluster utilization. The output is recorded in
//! EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_cluster
//! ```

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use chat_hpc::scheduler::ServiceSpec;
use chat_hpc::slurm::ClusterSpec;
use chat_hpc::stack::{ChatAiStack, StackConfig};
use chat_hpc::util::bench::stats;

fn main() -> anyhow::Result<()> {
    println!("serve_cluster — full-system E2E on the KISSKI geometry (10 nodes x 4 GPUs)\n");

    let services = vec![
        ServiceSpec::pjrt_tiny(), // REAL model: AOT JAX/Pallas via PJRT
        ServiceSpec::sim("intel-neural-7b", 0.05),
        ServiceSpec::sim("mixtral-8x7b", 0.05),
    ];
    let stack = ChatAiStack::start(StackConfig {
        cluster: ClusterSpec::kisski(),
        services,
        load_time_scale: 0.01,
        keepalive: Duration::from_millis(100),
        with_external: true,
        ..Default::default()
    })?;

    println!("waiting for all services to become ready (cold starts)...");
    for svc in ["tiny", "intel-neural-7b", "mixtral-8x7b"] {
        let t = Instant::now();
        stack.wait_ready(svc, Duration::from_secs(120))?;
        println!("  {svc:<18} ready after {:.2}s", t.elapsed().as_secs_f64());
    }

    {
        let slurm = stack.slurm.lock().unwrap();
        let free = slurm.free_gpus();
        println!("\ncluster: {} free GPUs of 40 after service placement", free);
    }

    // ---- batched workload: concurrent clients per model -----------------
    println!("\nserving 60s-equivalent batched workload (16 clients/model)...\n");
    let mut rows = Vec::new();
    for (svc, prompt, clients, secs) in [
        ("tiny", "Hello world", 8, 10.0),
        ("intel-neural-7b", "count from 1 to 10", 16, 10.0),
        ("mixtral-8x7b", "count from 1 to 10", 16, 10.0),
    ] {
        let ok = AtomicU64::new(0);
        let err = AtomicU64::new(0);
        let latencies = std::sync::Mutex::new(Vec::new());
        let deadline = Instant::now() + Duration::from_secs_f64(secs);
        std::thread::scope(|s| {
            for _ in 0..clients {
                s.spawn(|| {
                    while Instant::now() < deadline {
                        let t = Instant::now();
                        match stack.chat(svc, prompt) {
                            Ok((200, _)) => {
                                ok.fetch_add(1, Ordering::Relaxed);
                                latencies.lock().unwrap().push(t.elapsed().as_secs_f64());
                            }
                            _ => {
                                err.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                });
            }
        });
        let n_ok = ok.load(Ordering::Relaxed);
        let lat = latencies.into_inner().unwrap();
        let s = if lat.is_empty() { stats(&[0.0]) } else { stats(&lat) };
        rows.push((svc, n_ok, err.load(Ordering::Relaxed), n_ok as f64 / secs, s));
    }

    println!("| model | ok | err | RPS | p50 ms | p95 ms | mean ms |");
    println!("|---|---|---|---|---|---|---|");
    for (svc, ok, err, rps, s) in &rows {
        println!(
            "| {svc} | {ok} | {err} | {rps:.1} | {:.1} | {:.1} | {:.1} |",
            s.p50 * 1e3,
            s.p95 * 1e3,
            s.mean * 1e3
        );
    }

    // ---- verify the real model produced deterministic output ------------
    let (status, body) = stack.chat("tiny", "Hello world")?;
    anyhow::ensure!(status == 200, "tiny chat failed: {body:?}");
    let text = body
        .at(&["choices", "0", "message", "content"])
        .and_then(|c| c.as_str())
        .unwrap_or("")
        .to_string();
    println!("\ntiny (real PJRT model) sample output: {:?}", &text[..text.len().min(60)]);

    // ---- metrics + accounting -------------------------------------------
    let total_reqs = stack.log.len();
    println!("\ntotal requests logged: {total_reqs}");
    let usage = stack.slurm.lock().unwrap().account_usage("svc-chat-ai");
    println!(
        "functional-account accounting: {} jobs submitted, {:.0} GPU-seconds",
        usage.jobs_submitted, usage.gpu_secs
    );
    println!("\nserve_cluster OK");
    Ok(())
}
