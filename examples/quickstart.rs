//! Quickstart: boot the whole Chat AI stack in-process and chat with a
//! model through the full request path (gateway → SSH ForceCommand →
//! cloud interface → vLLM-like engine).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use std::time::Duration;

use chat_hpc::scheduler::ServiceSpec;
use chat_hpc::stack::{ChatAiStack, StackConfig};

fn main() -> anyhow::Result<()> {
    println!("chat-hpc quickstart — booting the Figure-1 stack in-process\n");

    let stack = ChatAiStack::start(StackConfig {
        services: vec![ServiceSpec::sim("intel-neural-7b", 0.02)],
        load_time_scale: 0.01, // 30 s model load -> 300 ms
        keepalive: Duration::from_millis(100),
        ..Default::default()
    })?;

    println!("gateway listening on {}", stack.gateway_url());
    println!("waiting for the scheduler to bring up an instance (cold start)...");
    stack.wait_ready("intel-neural-7b", Duration::from_secs(30))?;
    println!("instance ready; routing table:");
    for inst in stack.scheduler.routing.instances("intel-neural-7b") {
        println!(
            "  job {} on {} port {} ready={}",
            inst.job_id, inst.node, inst.port, inst.ready
        );
    }

    println!("\n>>> user: count from 1 to 10");
    let (status, body) = stack.chat("intel-neural-7b", "count from 1 to 10")?;
    let text = body
        .at(&["choices", "0", "message", "content"])
        .and_then(|c| c.as_str())
        .unwrap_or("<no content>");
    println!("<<< assistant ({status}): {text}");

    print!("\n>>> streaming the same prompt: ");
    let streamed = stack.chat_stream("intel-neural-7b", "count from 1 to 10")?;
    println!("{streamed}");

    println!("\nSlurm view of the service:");
    for job in stack.slurm.lock().unwrap().squeue() {
        println!(
            "  job {} {} [{}] on {:?} ({})",
            job.id,
            job.name,
            job.state.as_str(),
            job.nodes,
            job.comment
        );
    }

    println!("\nusage log (the ONLY per-request data the server keeps):");
    for e in stack.log.entries() {
        println!("  ts={}us user={} model={}", e.ts_us, e.user, e.model);
    }

    println!("\nquickstart OK");
    Ok(())
}
