//! Autoscaling demo (§5.6/§7.1.1): watch the scheduler react to a demand
//! spike — scale-up on windowed average concurrency, cold-start lag, then
//! scale-down when the spike passes.
//!
//! Runs against a simulated clock, so "minutes" elapse in milliseconds.
//!
//! ```bash
//! cargo run --release --example autoscale_demo
//! ```

use std::sync::{Arc, Mutex};
use std::time::Duration;

use chat_hpc::scheduler::{
    MockLauncher, SchedulerConfig, ServiceScheduler, ServiceSpec, BackendKind,
};
use chat_hpc::slurm::{ClusterSpec, SlurmSim};
use chat_hpc::util::clock::SimClock;
use chat_hpc::util::metrics::Registry;

fn main() -> anyhow::Result<()> {
    println!("autoscale_demo — demand spike against the Slurm-native scheduler\n");

    let slurm = Arc::new(Mutex::new(SlurmSim::new(ClusterSpec::kisski())));
    let clock = SimClock::new();
    let launcher = MockLauncher::new();
    let service = ServiceSpec {
        name: "llama3-70b".into(),
        min_instances: 1,
        max_instances: 6,
        target_concurrency: 4.0,
        gpus: 4,
        cpus: 16,
        mem_gb: 256,
        walltime: Duration::from_secs(12 * 3600),
        max_scavengers: 0,
        keep_alive: Duration::ZERO,
        backend: BackendKind::Sim { profile: "llama3-70b".into(), time_scale: 0.0 },
    };
    let sched = ServiceScheduler::new(
        slurm.clone(),
        clock.clone(),
        launcher.clone(),
        vec![service],
        SchedulerConfig::default(),
        Registry::new(),
    );

    println!("phase 1: idle — the scheduler holds min_instances=1");
    let mut guards = Vec::new();
    let mut print_state = |label: &str, sched: &ServiceScheduler, t_min: f64| {
        let total = sched.routing.instances("llama3-70b").len();
        let ready = sched.routing.ready_instances("llama3-70b").len();
        let avg = sched.demand.average("llama3-70b");
        println!(
            "  t={t_min:>5.1}min  {label:<22} instances={total} ready={ready} avg_concurrency={avg:.1}"
        );
    };

    // Each loop iteration = one 5 s keepalive tick.
    let mut tick = |sched: &ServiceScheduler, launcher: &MockLauncher, n: u32, ready: bool| {
        for _ in 0..n {
            clock.advance(Duration::from_secs(5));
            sched.run_once();
            if ready {
                launcher.all_healthy();
            }
        }
    };

    tick(&sched, &launcher, 12, true); // 1 minute
    print_state("idle", &sched, 1.0);

    println!("\nphase 2: spike — 20 concurrent requests arrive and stay");
    for _ in 0..20 {
        guards.push(sched.demand.begin("llama3-70b"));
    }
    for minute in [2.0, 3.0, 4.0, 5.0] {
        tick(&sched, &launcher, 12, false); // cold start: not healthy yet
        print_state("spike (cold start)", &sched, minute);
    }
    println!("  (instances exist but aren't READY: the 70B cold start, §7.1.1)");

    println!("\nphase 3: models finish loading");
    launcher.all_healthy();
    tick(&sched, &launcher, 12, true);
    print_state("spike (warm)", &sched, 6.0);

    println!("\nphase 4: spike ends — scale-down after the demand window drains");
    guards.clear();
    for minute in [7.0, 8.0, 9.0, 10.0, 12.0] {
        tick(&sched, &launcher, 12, true);
        print_state("drain", &sched, minute);
    }

    let free = slurm.lock().unwrap().free_gpus();
    println!("\nGPUs returned to the batch pool: {free}/40 free");
    println!("autoscale_demo OK");
    Ok(())
}
