//! Adoption report (Figures 3–5): run the adoption simulator through the
//! real analytics pipeline and print the three figures as ASCII series.
//!
//! ```bash
//! cargo run --release --example adoption_report
//! ```

use chat_hpc::analytics::{aggregate_daily, AdoptionConfig, AdoptionSim, RequestLog};
use chat_hpc::analytics::adoption::{date_label, EXTERNAL_MODELS};

fn bar(value: f64, max: f64, width: usize) -> String {
    let n = ((value / max.max(1.0)) * width as f64).round() as usize;
    "█".repeat(n.min(width))
}

fn main() -> anyhow::Result<()> {
    println!("adoption_report — regenerating Figures 3-5 from a simulated trace\n");
    let cfg = AdoptionConfig::default(); // Feb 22 - Jul 30 2024, paper scale
    let log = RequestLog::new();
    let summary = AdoptionSim::new(cfg.clone()).run(&log);
    let days = aggregate_daily(&log, cfg.days, EXTERNAL_MODELS, date_label);

    println!(
        "trace: {} users, {} requests over {} days\n",
        summary.total_users, summary.total_requests, cfg.days
    );

    // ---- Figure 3: total distinct users ---------------------------------
    println!("## Figure 3 — total distinct users (weekly samples)");
    let max_users = days.last().map(|d| d.total_users as f64).unwrap_or(1.0);
    for d in days.iter().step_by(7) {
        println!(
            "{} {:>6} {}",
            d.date,
            d.total_users,
            bar(d.total_users as f64, max_users, 50)
        );
    }

    // ---- Figure 4: daily users (new vs returning) -----------------------
    println!("\n## Figure 4 — daily users (weekly samples; n=new, r=returning)");
    let max_daily = days.iter().map(|d| d.daily_users()).max().unwrap_or(1) as f64;
    for d in days.iter().step_by(7) {
        println!(
            "{} n={:>4} r={:>4} {}",
            d.date,
            d.new_users,
            d.returning_users,
            bar(d.daily_users() as f64, max_daily, 50)
        );
    }

    // ---- Figure 5: requests/day, internal vs external -------------------
    println!("\n## Figure 5 — inference requests per day (weekly samples; i=internal, e=external)");
    let max_req = days.iter().map(|d| d.total_requests()).max().unwrap_or(1) as f64;
    for d in days.iter().step_by(7) {
        println!(
            "{} i={:>6} e={:>5} {}",
            d.date,
            d.internal_requests,
            d.external_requests,
            bar(d.total_requests() as f64, max_req, 50)
        );
    }

    // ---- headline checks against §6.4 -----------------------------------
    println!("\n## §6.4 calibration checks");
    let day_3mo = 90usize.min(days.len() - 1);
    let day_jun = 125usize.min(days.len() - 1);
    println!("  users after 3 months: {} (paper: >6000)", days[day_3mo].total_users);
    println!("  users by end of June:  {} (paper: ~9000)", days[day_jun].total_users);
    let workday_users: Vec<u64> = days
        .iter()
        .filter(|d| {
            !chat_hpc::analytics::adoption::is_weekend(d.day) && (60..120).contains(&d.day)
        })
        .map(|d| d.daily_users())
        .collect();
    let avg_wd = workday_users.iter().sum::<u64>() as f64 / workday_users.len().max(1) as f64;
    println!("  avg workday users (Apr-Jun): {avg_wd:.0} (paper: 400-500)");
    println!("  total messages: {} (paper: >350000)", summary.total_requests);
    let internal: u64 = days.iter().map(|d| d.internal_requests).sum();
    let external: u64 = days.iter().map(|d| d.external_requests).sum();
    println!(
        "  internal vs external share: {:.0}% / {:.0}% (paper: internal dominates)",
        100.0 * internal as f64 / (internal + external) as f64,
        100.0 * external as f64 / (internal + external) as f64
    );

    println!("\nadoption_report OK");
    Ok(())
}
