//! Security drill (§6.1): run the paper's attack scenarios against a live
//! stack and print the defense-in-depth scorecard.
//!
//! ```bash
//! cargo run --release --example security_drill
//! ```

use std::time::Duration;

use chat_hpc::scheduler::ServiceSpec;
use chat_hpc::sshsim::{KeyPair, SshClient};
use chat_hpc::stack::{ChatAiStack, StackConfig};
use chat_hpc::util::http;

fn verdict(ok: bool) -> &'static str {
    if ok {
        "DEFENDED"
    } else {
        "BREACHED !!"
    }
}

fn main() -> anyhow::Result<()> {
    println!("security_drill — §6.1 attack scenarios against a live stack\n");
    let stack = ChatAiStack::start(StackConfig {
        services: vec![ServiceSpec::sim("intel-neural-7b", 0.0)],
        ..Default::default()
    })?;
    stack.wait_ready("intel-neural-7b", Duration::from_secs(15))?;

    // -- scenario 1: anonymous internet user probes the gateway ----------
    println!("scenario 1: unauthenticated access to the inference API");
    let r = http::request(
        "POST",
        &format!("{}/v1/m/intel-neural-7b/", stack.gateway_url()),
        &[],
        b"{}",
    )?;
    println!("  gateway answered {} -> {}\n", r.status, verdict(r.status == 401));

    // -- scenario 2: compromised web server, stolen SSH key ---------------
    println!("scenario 2: web server fully compromised; attacker holds the SSH key");
    let stolen = KeyPair::generate(0xE5C); // the stack's key material
    let ssh = SshClient::connect(&stack.ssh_server.addr.to_string(), &stolen)?;
    let attacks = [
        "/bin/bash -i",
        "cat ~/.ssh/id_rsa",
        "srun --gres=gpu:4 ./cryptominer",
        "scancel --all",
    ];
    let mut all_blocked = true;
    for attempt in attacks {
        let reply = ssh.exec(attempt, b"")?;
        let blocked = reply.exit_code == 2;
        all_blocked &= blocked;
        println!("  exec {attempt:?} -> exit {} ({})", reply.exit_code, verdict(blocked));
    }
    println!(
        "  ForceCommand interceptions recorded by sshd: {}\n",
        stack
            .ssh_server
            .stats
            .forced_commands
            .load(std::sync::atomic::Ordering::Relaxed)
    );
    assert!(all_blocked);

    // -- scenario 3: injection through the permitted verbs ----------------
    println!("scenario 3: command injection inside permitted verbs");
    for attempt in
        ["infer intel-neural-7b; scancel --all", "infer $(reboot)", "probe x|sh"]
    {
        let reply = ssh.exec(attempt, b"{}")?;
        println!(
            "  {attempt:?} -> exit {} ({})",
            reply.exit_code,
            verdict(reply.exit_code == 2)
        );
    }

    // -- scenario 4: rogue key without authorized_keys entry --------------
    println!("\nscenario 4: attacker-generated key (not in authorized_keys)");
    let rogue = KeyPair::generate(0xDEAD);
    let rejected = SshClient::connect(&stack.ssh_server.addr.to_string(), &rogue).is_err();
    println!("  handshake -> {}\n", verdict(rejected));

    // -- scenario 5: data theft -------------------------------------------
    println!("scenario 5: attacker dumps all server-side state hunting conversations");
    let secret = "TOP-SECRET-RESEARCH-IDEA";
    let _ = stack.chat("intel-neural-7b", secret)?;
    let mut leaked = false;
    leaked |= stack.log.entries().iter().any(|e| format!("{e:?}").contains(secret));
    leaked |= stack.metrics.render().contains(secret);
    leaked |= stack
        .slurm
        .lock()
        .unwrap()
        .squeue()
        .iter()
        .any(|j| j.comment.contains(secret));
    println!("  prompt text found in logs/metrics/slurm state? {}", verdict(!leaked));
    println!(
        "  stored per-request fields: user id, timestamp, model — nothing else (§6.2)\n"
    );

    println!("security_drill OK — all scenarios defended");
    Ok(())
}
