#!/usr/bin/env bash
# Tier-1 CI pipeline — exactly what .github/workflows/ci.yml runs
# (there as a lint + {debug,release} test matrix + bench-smoke job).
# Usage: ./ci.sh
set -euo pipefail
cd "$(dirname "$0")"

echo "==> cargo fmt --check"
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "    rustfmt not installed; skipping (CI installs it)"
fi

echo "==> cargo clippy --all-targets -- -D warnings"
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "    clippy not installed; skipping (CI installs it)"
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo build --release --benches"
# Benches are harness=false main()s outside the test graph; building them
# here keeps the paper-figure reproductions from rotting outside tier-1.
cargo build --release --benches

echo "==> cargo test -q (debug: keeps the engine/allocator debug_assertions invariant checks live)"
cargo test -q

echo "==> cargo test --release -q"
cargo test --release -q

# Seed-replay determinism: the virtual-time serving path must be a pure
# function of its seed. The replay suite runs in two separate processes
# and the trace artifacts are byte-compared; then the fig3 serving sweep
# (one diurnal hour, 100k-user population) runs twice and its BENCH json
# is byte-compared. `timeout 60` on the pre-built second sweep enforces
# the "simulated hour in under a minute of wall-clock" bound.
echo "==> sim-determinism: seed-replay trace diff"
SIM_TRACE_OUT="$PWD/target/sim_trace_a.txt" cargo test --release --test sim_determinism -q
SIM_TRACE_OUT="$PWD/target/sim_trace_b.txt" cargo test --release --test sim_determinism -q
cmp target/sim_trace_a.txt target/sim_trace_b.txt

# Dual-channel streaming: the stream_modes suite runs the e2e matrix
# (dual on/off SSE byte-identity, cancel/failure slot accounting, sim
# twins); then the seed-replay suite re-runs with dual-channel enabled —
# the flag is trace-neutral by contract (stack/sim.rs), so the trace
# artifact must be byte-identical to run A above.
echo "==> stream-modes: dual-channel e2e suite"
cargo test --release --test stream_modes -q
echo "==> stream-modes: seed-replay with SIM_DUAL_CHANNEL=1"
SIM_DUAL_CHANNEL=1 SIM_TRACE_OUT="$PWD/target/sim_trace_dual.txt" \
    cargo test --release --test sim_determinism -q
cmp target/sim_trace_a.txt target/sim_trace_dual.txt

echo "==> sim-determinism: fig3 serving sweep byte-compare"
cargo bench --bench fig3_users -- --serving --seed 7
mv BENCH_fig3_serving.json target/BENCH_fig3_serving_a.json
timeout 60 cargo bench --bench fig3_users -- --serving --seed 7
cmp target/BENCH_fig3_serving_a.json BENCH_fig3_serving.json

# Paper-figure smoke runs: tiny sweeps, seconds not minutes — the benches
# must not just compile but *run* and emit their machine-readable results
# with every required sweep present.
echo "==> bench smoke: table1_latency"
cargo bench --bench table1_latency -- --smoke
echo "==> bench smoke: table2_throughput"
cargo bench --bench table2_throughput -- --smoke
echo "==> bench smoke: ablation_scheduler"
cargo bench --bench ablation_scheduler -- --smoke
echo "==> bench smoke: stream_saturation"
cargo bench --bench stream_saturation -- --smoke

# Chaos drills: the failure-policy matrix (preemption storm, lane flap,
# gray node, upstream outage + flash crowd) under virtual time. The drills
# are deterministic by contract, so two runs with the same seed must emit
# a byte-identical BENCH_chaos.json.
echo "==> chaos-smoke: chaos_drills determinism diff"
cargo bench --bench chaos_drills -- --smoke --seed 7
mv BENCH_chaos.json target/BENCH_chaos_a.json
cargo bench --bench chaos_drills -- --smoke --seed 7
cmp target/BENCH_chaos_a.json BENCH_chaos.json

# Scenario matrix: five trace-driven drills (diurnal + scavenger, flash
# crowd vs scale-from-zero, tiered deadlines, prefill flood, coordinated
# failure drill) under virtual time. Each drill already replays in-process;
# here the whole matrix runs twice in separate processes and both the
# concatenated trace artifact and BENCH_scenarios.json are byte-compared.
echo "==> scenario-smoke: scenario_matrix determinism diff"
SCENARIO_TRACE_OUT="$PWD/target/scenario_trace_a.txt" cargo bench --bench scenario_matrix -- --smoke --seed 7
mv BENCH_scenarios.json target/BENCH_scenarios_a.json
SCENARIO_TRACE_OUT="$PWD/target/scenario_trace_b.txt" cargo bench --bench scenario_matrix -- --smoke --seed 7
cmp target/scenario_trace_a.txt target/scenario_trace_b.txt
cmp target/BENCH_scenarios_a.json BENCH_scenarios.json

# Fleet routing: session-affine vs. random placement over a 3-replica
# group (affine must land >= 1.5x the prefix-cache hit-token rate) plus
# the scale-from-zero drill (exactly one weight load for five requests).
# Deterministic by contract: two runs with the same seed must emit
# byte-identical traces and a byte-identical BENCH_fleet.json.
echo "==> fleet-smoke: fleet_routing determinism diff"
FLEET_TRACE_OUT="$PWD/target/fleet_trace_a.txt" cargo bench --bench fleet_routing -- --smoke --seed 7
mv BENCH_fleet.json target/BENCH_fleet_a.json
FLEET_TRACE_OUT="$PWD/target/fleet_trace_b.txt" cargo bench --bench fleet_routing -- --smoke --seed 7
cmp target/fleet_trace_a.txt target/fleet_trace_b.txt
cmp target/BENCH_fleet_a.json BENCH_fleet.json

echo "==> validate BENCH_*.json schemas"
if python3 --version >/dev/null 2>&1; then
    python3 scripts/check_bench.py BENCH_table1.json \
        probe_local_proxy ssh_command probe_gpu_node llm_first_token
    python3 scripts/check_bench.py BENCH_table2.json \
        gateway web_interface middleware ssh_service_node ssh_gpu_node \
        word_7b sentence_7b sentence_8x7b sentence_72b sentence_70b \
        pool_n1 pool_n2 abandon_run_to_completion abandon_cancel \
        multiturn_cache_off multiturn_cache_on
    python3 scripts/check_bench.py BENCH_ablation_scheduler.json \
        scavenger_off scavenger_on
    python3 scripts/check_bench.py BENCH_fig3_serving.json \
        hour_q1 hour_q2 hour_q3 hour_q4 overall
    python3 scripts/check_bench.py BENCH_stream.json \
        single_channel dual_channel dual_zero_copy
    python3 scripts/check_bench.py --passed BENCH_chaos.json \
        preemption_storm lane_flap gray_node upstream_outage
    python3 scripts/check_bench.py BENCH_fleet.json \
        affine random scale_from_zero
    python3 scripts/check_bench.py --passed BENCH_scenarios.json \
        diurnal_scavenger flash_crowd tiered_deadlines prefill_flood failure_drill
else
    echo "    python3 not installed; skipping schema validation (CI runs it)"
fi

echo "CI OK"
