#!/usr/bin/env bash
# Tier-1 CI pipeline — exactly what .github/workflows/ci.yml runs.
# Usage: ./ci.sh
set -euo pipefail
cd "$(dirname "$0")"

echo "==> cargo fmt --check"
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "    rustfmt not installed; skipping (CI installs it)"
fi

echo "==> cargo clippy --all-targets -- -D warnings"
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "    clippy not installed; skipping (CI installs it)"
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo build --release --benches"
# Benches are harness=false main()s outside the test graph; building them
# here keeps the paper-figure reproductions from rotting outside tier-1.
cargo build --release --benches

echo "==> cargo test -q"
cargo test -q

echo "CI OK"
